"""Multi-query serving engine: one session, thousands of concurrent queries.

The paper's premise is relational query processing as a *service* over big
matrix data; its Spark prototype amortizes optimization across a query
stream. This module is that serving tier for the jax engine:

* ``submit()`` accepts a stream of logical plans (``Expr`` or ``Matrix``)
  from many clients/tenants and returns a ``Ticket`` (an async handle);
  worker threads drain the queue in batches.
* **Cross-query CSE** — all queries over one catalog version lower into a
  single shared hash-consing arena (``plan.builder.SharedBuildState``):
  a subplan any earlier query lowered resolves to the same shared node
  id, and a shared LRU of materialized node results
  (``core.plancache.VersionedLRU``) turns that structural sharing into
  *execution* sharing — overlapping pipelines compute each shared
  subexpression once per catalog version. A whole-query repeat is a root
  hit and returns without touching the evaluator.
* **Shared optimizer state** — optimize results, the memo search's
  physical-cost cache and the catalog ``Leaves`` view are shared per
  catalog version, so overlapping queries cost each shared candidate
  subexpression once (``core.optimizer.optimize(cost_cache=...,
  leaves=...)``).
* **Batched leaf scans** — before a drained batch executes, the distinct
  leaves referenced by the whole batch are materialized once each into
  the shared result cache (one scan per leaf per batch, not per query).
* **Versioned caches** — every shared structure is keyed by the catalog
  version (bumped by ``Session.load``): a leaf rebind retires the old
  arena/results atomically for *new* queries while in-flight queries keep
  the version they started against. Invariant: every cache keyed on
  data-dependent annotations carries the catalog version.
* **Admission control** — a bounded queue plus per-tenant in-flight
  quotas reject excess load at submit time (``AdmissionError``), and
  per-tenant result-cache budgets stop one tenant's churn from flushing
  another's hot entries.

``cse=False`` disables the shared result cache and the arena reuse, and
executes each query standalone through the session's (jit-staged) path —
the baseline the serving benchmark compares against.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional

from repro.core import optimizer as optmod
from repro.core.expr import Expr, signature
from repro.core.plancache import VersionedLRU
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TRACER
from repro.plan import builder as buildermod
from repro.plan.executor import PlanExecutor
from repro.plan import ops as P


class AdmissionError(RuntimeError):
    """Submit rejected by admission control (queue full / tenant over
    budget). Clients are expected to back off and retry."""


class Ticket:
    """Async handle for one submitted query."""

    def __init__(self, query: Expr, tenant: str):
        self.query = query
        self.tenant = tenant
        self.submitted_at = time.perf_counter()
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.reused_nodes = 0        # node results served from the shared LRU
        self.evaluated_nodes = 0
        self.trace = None            # obs.trace.Trace when sampled at submit
        self.opt = None              # OptimizeResult (predicted nnz → ledger)
        self._done = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None

    @property
    def trace_id(self) -> Optional[str]:
        return self.trace.trace_id if self.trace is not None else None

    # -- worker side ----------------------------------------------------------
    def _finish(self, result=None, error: Optional[BaseException] = None):
        self._result, self._error = result, error
        self.finished_at = time.perf_counter()
        self._done.set()

    # -- client side ----------------------------------------------------------
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError("query still in flight")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency(self) -> float:
        """Submit→finish wall seconds (meaningful once ``done()``)."""
        return (self.finished_at or time.perf_counter()) - self.submitted_at


@dataclasses.dataclass
class _VersionState:
    """All cross-query shared state for one (catalog version × settings):
    the hash-consing arena, an immutable catalog snapshot, per-version
    optimizer caches, and the extracted-plan cache. Retired wholesale when
    the catalog version moves on (old instances keep serving their
    in-flight queries until unreferenced)."""

    key: tuple
    env: Dict                       # catalog snapshot (name → BlockMatrix)
    shared: buildermod.SharedBuildState
    leaves: object                  # plan.masks.Leaves over the snapshot
    cost_cache: Dict = dataclasses.field(default_factory=dict)
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    opt_cache: Optional[VersionedLRU] = None
    plans: Optional[VersionedLRU] = None       # optimized expr → SharedLowering
    plan_locks: Dict[int, threading.Lock] = \
        dataclasses.field(default_factory=dict)


class _NodeCache:
    """Adapter from the executor's ``get(plan, node)/put`` seam to the
    engine's shared result LRU, keyed by (version-state key, shared node
    id) and attributed to the submitting tenant for budget accounting."""

    def __init__(self, results: VersionedLRU, state_key: tuple, tenant: str):
        self._results = results
        self._state_key = state_key
        self._tenant = tenant

    def get(self, plan: P.PhysicalPlan, node: P.PhysicalNode):
        return self._results.get((self._state_key,
                                  node.meta.get("shared_id", node.op_id)))

    def put(self, plan: P.PhysicalPlan, node: P.PhysicalNode, result):
        self._results.put(
            (self._state_key, node.meta.get("shared_id", node.op_id)),
            result, tenant=self._tenant)


class ServeEngine:
    """Serving front end over one ``Session`` (see module docstring).

    Parameters
    ----------
    n_threads: worker threads draining the submit queue.
    max_queue: admission bound on queued tickets (global).
    tenant_max_inflight: admission bound on queued+running per tenant.
    cse: enable the cross-query shared arena + result cache.
    result_entries / tenant_result_budget: shared result LRU capacity and
        the per-tenant entry budget within it.
    batch_max: tickets drained per worker wakeup (the leaf-scan batching
        window).
    """

    # snapshot() compatibility keys, all registry-backed (``serve_<name>``)
    _COUNTERS = (
        "submitted", "completed", "errors",
        "rejected_queue", "rejected_tenant",
        "root_hits", "node_reuses", "node_evals",
        "inter_query_cse_nodes",
        "leaf_scans", "leaf_refs", "batches",
        "refits", "refit_rows",
    )

    def __init__(self, session, *, n_threads: int = 2, max_queue: int = 1024,
                 tenant_max_inflight: Optional[int] = None, cse: bool = True,
                 result_entries: int = 1024,
                 tenant_result_budget: Optional[int] = None,
                 plan_entries: int = 128, opt_entries: int = 256,
                 batch_max: int = 32, keep_versions: int = 2,
                 registry: Optional[MetricsRegistry] = None,
                 trace_sample: Optional[float] = None,
                 ledger=None, ledger_root_hits: bool = False,
                 measure_comm: bool = False,
                 refit_every: Optional[int] = None):
        self.session = session
        self.cse = cse
        self.max_queue = max_queue
        self.tenant_max_inflight = tenant_max_inflight
        self.batch_max = batch_max
        self._plan_entries = plan_entries
        self._opt_entries = opt_entries
        # per-engine registry by default: tests assert exact counter
        # values per engine; pass ``obs.metrics.REGISTRY`` to aggregate
        # process-wide instead
        self.metrics = registry if registry is not None else MetricsRegistry()
        # engine-level sampling override: None defers to the global
        # tracer's rate (REPRO_TRACE_SAMPLE); a float forces this
        # engine's own deterministic 1-in-N choice
        self.trace_sample = trace_sample
        self._trace_seq = 0
        # optional obs.ledger.CostLedger: one predicted-vs-actual row per
        # executed plan; measure_comm additionally compiles the staged
        # SPMD program for HLO-measured collective bytes (mesh runs only).
        # Root hits execute nothing (the row would record a cache lookup,
        # useless for cost-model re-fitting) so they are skipped unless
        # ledger_root_hits is set — this keeps the ledger off the
        # hottest serving path.
        self.ledger = ledger
        self.ledger_root_hits = ledger_root_hits
        self.measure_comm = measure_comm
        # online calibration: with a ledger AND a session cost model,
        # every ``refit_every`` executed (ledgered) plans a background
        # daemon thread re-fits the model from the accumulated rows. A
        # drift-exceeding fit bumps ``cost_model.version``, which is
        # part of the state key below — new queries admit the refreshed
        # coefficients while in-flight queries keep the version-state
        # they started against (the same retire machinery a catalog
        # rebind uses). The trigger interval backs off exponentially
        # while fits keep converging (no version bump) and snaps back
        # to ``refit_every`` on a bump: a converged model stops paying
        # fit CPU against the serving threads, a regime change is
        # tracked closely again.
        self.refit_every = refit_every
        self._refit_rows_seen = 0
        self._refit_interval = refit_every
        self._refit_last_at = 0
        self._refit_lock = threading.Lock()
        self._refit_thread: Optional[threading.Thread] = None
        self._results = VersionedLRU(result_entries,
                                     tenant_budget=tenant_result_budget,
                                     name="results", registry=self.metrics)
        self._counters = {name: self.metrics.counter("serve_" + name)
                          for name in self._COUNTERS}
        self._arena_nodes = self.metrics.gauge("serve_arena_nodes")
        self._costmodel_version = self.metrics.gauge(
            "serve_costmodel_version")
        self._latency = self.metrics.histogram("serve_latency_s")
        self._queue_wait = self.metrics.histogram("serve_queue_wait_s")
        self._states: "deque[_VersionState]" = deque(maxlen=keep_versions)
        self._queue: "deque[Ticket]" = deque()
        self._inflight: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._stop = False
        self._threads = [
            threading.Thread(target=self._worker_loop, daemon=True,
                             name=f"serve-worker-{i}")
            for i in range(n_threads)]
        for t in self._threads:
            t.start()

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        with self._lock:
            self._stop = True
            self._work.notify_all()
        for t in self._threads:
            t.join()

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- client API -----------------------------------------------------------
    def submit(self, query, tenant: str = "default") -> Ticket:
        """Enqueue one logical plan (an ``Expr`` or a ``core.api.Matrix``);
        raises ``AdmissionError`` when the queue or the tenant budget is
        full."""
        expr = query.plan if hasattr(query, "plan") else query
        if not isinstance(expr, Expr):
            raise TypeError(f"not a logical plan: {type(query)}")
        ticket = Ticket(expr, tenant)
        with self._lock:
            if self._stop:
                raise RuntimeError("engine is closed")
            if len(self._queue) >= self.max_queue:
                self._counters["rejected_queue"].inc()
                raise AdmissionError(
                    f"queue full ({self.max_queue} tickets)")
            if (self.tenant_max_inflight is not None
                    and self._inflight.get(tenant, 0)
                    >= self.tenant_max_inflight):
                self._counters["rejected_tenant"].inc()
                raise AdmissionError(
                    f"tenant {tenant!r} over budget "
                    f"({self.tenant_max_inflight} in flight)")
            self._inflight[tenant] = self._inflight.get(tenant, 0) + 1
            self._counters["submitted"].inc()
            sample = self._sample_locked()
            self._queue.append(ticket)
            self._work.notify()
        # trace starts at submit (client thread) and is *activated* on
        # whichever worker thread executes the ticket — queue wait is the
        # gap between the two
        ticket.trace = TRACER.start("query", sample=sample, tenant=tenant,
                                    query=signature(expr))
        return ticket

    def _sample_locked(self) -> Optional[bool]:
        """Engine-level trace sampling decision (``self._lock`` held).
        None → defer to the global tracer's rate."""
        r = self.trace_sample
        if r is None:
            return None
        if r <= 0.0:
            return False
        if r >= 1.0:
            return True
        period = max(1, round(1.0 / r))
        self._trace_seq += 1
        return self._trace_seq % period == 0

    def run(self, query, tenant: str = "default",
            timeout: Optional[float] = None):
        """Submit and wait (the synchronous convenience path)."""
        return self.submit(query, tenant=tenant).result(timeout)

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every submitted ticket has finished."""
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            with self._lock:
                if not self._queue and not any(self._inflight.values()):
                    return
            time.sleep(0.001)
        raise TimeoutError("engine did not drain")

    # -- version-state management ---------------------------------------------
    def _state_key(self, version: int) -> tuple:
        import os
        s = self.session
        return (version, s.mode, s.block_size, s.use_bloom, s.n_workers,
                s._mesh_key(), os.environ.get("REPRO_KERNEL_BACKEND"),
                s._costmodel_key())

    def _current_state(self) -> _VersionState:
        """The shared state for the catalog as of *now*. The version is
        read on both sides of the snapshot so a concurrent ``load`` can
        never produce a state whose snapshot mixes versions."""
        from repro.plan import masks as masksmod
        s = self.session
        while True:
            v = s._env_version
            key = self._state_key(v)
            with self._lock:
                for st in self._states:
                    if st.key == key:
                        return st
            env = dict(s.env)
            if s._env_version != v:
                continue                      # rebind raced the snapshot
            st = _VersionState(
                key=key, env=env,
                shared=buildermod.SharedBuildState(
                    mode=s.mode, block_size=s.block_size,
                    use_bloom=s.use_bloom, n_workers=s.workers),
                leaves=masksmod.Leaves(env, s.block_size),
                opt_cache=VersionedLRU(self._opt_entries),
                plans=VersionedLRU(self._plan_entries))
            with self._lock:
                for other in self._states:
                    if other.key == key:      # another thread won the race
                        return other
                self._states.append(st)
            return st

    # -- worker side ----------------------------------------------------------
    def _finish_ticket(self, ticket: Ticket, result=None,
                       error: Optional[BaseException] = None) -> None:
        """The single completion site: every ticket — success, plan
        failure or execution failure — ends here exactly once, so
        ``completed``/``errors`` and the latency histogram can never
        drift from the ticket stream (previously three call sites
        incremented independently)."""
        ticket._finish(result=result, error=error)
        self._counters["errors" if error is not None
                       else "completed"].inc()
        self._latency.observe(ticket.latency)
        if ticket.trace is not None:
            ticket.trace.finish()

    def _worker_loop(self) -> None:
        while True:
            with self._lock:
                while not self._queue and not self._stop:
                    self._work.wait()
                if self._stop and not self._queue:
                    return
                batch: List[Ticket] = []
                while self._queue and len(batch) < self.batch_max:
                    batch.append(self._queue.popleft())
                self._counters["batches"].inc()
            state = self._current_state()
            lowered = [self._plan_ticket(state, t) for t in batch]
            if self.cse:
                t0 = time.perf_counter()
                self._prewarm_leaves(state, [p for p in lowered
                                             if p is not None])
                t1 = time.perf_counter()
                # batch-level phase, attributed to every traced ticket
                for ticket in batch:
                    if ticket.trace is not None:
                        with TRACER.activate(ticket.trace):
                            TRACER.add_event("batch_prewarm", t0, t1,
                                             batch=len(batch))
            for ticket, lw in zip(batch, lowered):
                try:
                    if lw is not None:
                        with TRACER.activate(ticket.trace):
                            self._execute(state, ticket, lw)
                except BaseException as e:      # propagate to the client
                    self._finish_ticket(ticket, error=e)
                finally:
                    with self._lock:
                        self._inflight[ticket.tenant] -= 1

    def _plan_ticket(self, state: _VersionState, ticket: Ticket
                     ) -> Optional[buildermod.SharedLowering]:
        """Optimize + lower one ticket against the shared per-version
        state; on failure the ticket is finished with the error and None
        is returned."""
        s = self.session
        try:
            ticket.started_at = time.perf_counter()
            self._queue_wait.observe(ticket.started_at
                                     - ticket.submitted_at)
            with TRACER.activate(ticket.trace):
                TRACER.add_event("queue_wait", ticket.submitted_at,
                                 ticket.started_at)
                TRACER.annotate(admitted_version=state.key[0])
                opt = state.opt_cache.get_or_create(
                    (ticket.query, s.search),
                    lambda: optmod.optimize(
                        ticket.query, search=s.search, session=s,
                        cost_cache=state.cost_cache, leaves=state.leaves),
                    tenant=ticket.tenant)
                ticket.opt = opt
                if not self.cse:
                    # standalone lowering: no shared arena, fresh/ per-expr
                    # plan via the session cache (jit-staged execution path)
                    plan = state.plans.get_or_create(
                        opt.plan, lambda: buildermod.build_plan(
                            opt.plan, mode=s.mode, block_size=s.block_size,
                            use_bloom=s.use_bloom, n_workers=s.workers),
                        tenant=ticket.tenant)
                    return buildermod.SharedLowering(
                        plan=plan, root_shared_id=-1, reused_nodes=0,
                        new_nodes=plan.n_nodes)
                def _lower():
                    with state.lock:
                        lw = buildermod.lower_shared(state.shared,
                                                     opt.plan)
                    self._counters["inter_query_cse_nodes"].inc(
                        lw.reused_nodes)
                    self._arena_nodes.set(len(state.shared.nodes))
                    return lw
                return state.plans.get_or_create(opt.plan, _lower,
                                                 tenant=ticket.tenant)
        except BaseException as e:
            self._finish_ticket(ticket, error=e)
            return None

    def _prewarm_leaves(self, state: _VersionState,
                        lowered: List[buildermod.SharedLowering]) -> None:
        """Batched leaf scans: materialize each distinct leaf the batch
        references once into the shared result cache."""
        from repro.core.executor import leaf_value
        seen = set()
        for lw in lowered:
            for node in lw.plan.nodes:
                if node.kind != P.LEAF:
                    continue
                key = (state.key, node.meta["shared_id"])
                self._counters["leaf_refs"].inc()
                if key in seen or self._results.get(key) is not None:
                    continue
                seen.add(key)
                val = leaf_value(node.expr, state.env, state.shared.block_size)
                self._results.put(key, val)
                self._counters["leaf_scans"].inc()

    # Minimum fraction of a plan's estimated flops that cached subresults
    # must cover before the engine prefers per-node eager reuse over the
    # jit-staged path (eager pays per-node dispatch overhead; staged pays
    # recomputing the overlap).
    EAGER_REUSE_MIN_COVERAGE = 0.5

    def _cse_coverage(self, state: _VersionState,
                      plan: P.PhysicalPlan) -> float:
        """Fraction of ``plan``'s estimated flops already materialized in
        the shared result cache: a cached node covers its whole subtree
        (evaluation stops there). Leaf hits contribute nothing — leaves
        carry no flops, and re-scanning one is cheap."""
        cached = {
            n.op_id for n in plan.nodes
            if n.kind != P.LEAF
            and (state.key, n.meta["shared_id"]) in self._results}
        if not cached:
            return 0.0
        need = set()
        stack = [plan.root]
        while stack:
            i = stack.pop()
            if i in need or i in cached:
                continue
            need.add(i)
            stack.extend(plan.node(i).children)
        total = plan.est_flops
        if total <= 0:
            return 1.0
        return 1.0 - sum(plan.node(i).est_flops for i in need) / total

    def _execute(self, state: _VersionState, ticket: Ticket,
                 lw: buildermod.SharedLowering) -> None:
        import jax
        t0 = time.perf_counter()
        exec_path = None
        ex = None
        if self.cse:
            root_key = (state.key,
                        lw.plan.node(lw.plan.root).meta["shared_id"])
            hit = self._results.get(root_key)
            if hit is not None:
                self._counters["root_hits"].inc()
                ticket.reused_nodes = lw.plan.n_nodes
                if self.ledger_root_hits:
                    self._ledger_row(state, ticket, lw.plan, "root_hit",
                                     time.perf_counter() - t0, 0.0)
                self._finish_ticket(ticket, result=hit)
                return
            if (self._cse_coverage(state, lw.plan)
                    >= self.EAGER_REUSE_MIN_COVERAGE):
                # substantial overlap with earlier queries: evaluate
                # eagerly, reusing every shared node result and publishing
                # the new ones (inter-query subexpression sharing)
                ex = PlanExecutor(
                    state.env, metrics=self.metrics,
                    node_cache=_NodeCache(self._results, state.key,
                                          ticket.tenant))
                out = ex.run(lw.plan)
                exec_path = "eager_reuse"
            else:
                # cold pipeline: run the fast (jit-staged) path once and
                # publish its root, which seeds subplan reuse for every
                # later query that embeds this one
                out, ex = self._run_staged(state, lw)
                self._results.put(root_key, out, tenant=ticket.tenant)
        else:
            out, ex = self._run_staged(state, lw)
        value = getattr(out, "value", out)
        try:
            jax.block_until_ready(value)       # latency = results on host
        except Exception:
            pass                               # host-side results (COO etc.)
        ticket.reused_nodes = ex.stats["node_reuses"]
        ticket.evaluated_nodes = ex.stats["node_evals"]
        self._counters["node_reuses"].inc(ex.stats["node_reuses"])
        self._counters["node_evals"].inc(ex.stats["node_evals"])
        if exec_path is None:
            from repro.obs.ledger import exec_path_of
            exec_path = exec_path_of(ex.stats)
        self._ledger_row(state, ticket, lw.plan, exec_path,
                         time.perf_counter() - t0,
                         ex.timings["compile_s"],
                         overflow=ex.stats["sparse_overflows"] > 0)
        self._finish_ticket(ticket, result=out)

    def _ledger_row(self, state: _VersionState, ticket: Ticket, plan,
                    exec_path: str, wall_s: float, compile_s: float,
                    overflow: bool = False) -> None:
        if self.ledger is None:
            return
        measured_comm = None
        if self.measure_comm:
            if self.session.mesh is not None:
                from repro.obs.ledger import measured_comm_bytes
                measured_comm = measured_comm_bytes(plan, state.env,
                                                    self.session.mesh)
            else:
                # single device: no interconnect, so the measured
                # collective traffic is exactly zero — recording it keeps
                # the predicted/measured comm gate meaningful off-mesh
                # (predicted must also be 0 for the ratio to stay 1.0)
                measured_comm = 0
        self.ledger.record(
            query=signature(ticket.query), plan=plan,
            exec_path=exec_path, wall_s=wall_s, compile_s=compile_s,
            measured_comm=measured_comm, overflow=overflow,
            opt=ticket.opt, trace_id=ticket.trace_id,
            tenant=ticket.tenant)
        if exec_path != "root_hit":
            self._maybe_refit()

    # -- online calibration ---------------------------------------------------

    # Each background refit fits from at most this many of the ledger's
    # most recent rows: bounded work per fit (a full-history refit would
    # grow O(n) per trigger, O(n²) over a serving session) that also
    # weights the fit toward the current workload regime.
    REFIT_WINDOW_ROWS = 512

    # Convergence backoff cap: while successive fits stay within the
    # model's drift threshold (no version bump) the trigger interval
    # doubles per fit, up to refit_every * this factor.
    REFIT_BACKOFF_MAX = 32

    def _maybe_refit(self) -> None:
        """Count one executed (ledgered) plan; when the backoff interval
        has elapsed, kick a background refit of the session cost model
        from the tail window of the ledger's in-memory rows. The hot
        path pays one lock + counter — fitting happens off-thread, and
        at most one refit runs at a time (a still-running fit skips the
        trigger rather than queue)."""
        if (self.refit_every is None
                or getattr(self.session, "cost_model", None) is None):
            return
        with self._refit_lock:
            self._refit_rows_seen += 1
            if (self._refit_rows_seen - self._refit_last_at
                    < self._refit_interval):
                return
            if (self._refit_thread is not None
                    and self._refit_thread.is_alive()):
                return
            self._refit_last_at = self._refit_rows_seen
            rows = self.ledger.rows()[-self.REFIT_WINDOW_ROWS:]
            t = threading.Thread(target=self._refit, args=(rows,),
                                 daemon=True, name="serve-refit")
            self._refit_thread = t
            t.start()

    def _refit(self, rows) -> None:
        model = self.session.cost_model
        v0 = model.version
        try:
            ok = model.fit_from_rows(rows)
        except Exception:
            ok = False
        if not ok:
            return
        self._counters["refits"].inc()
        self._counters["refit_rows"].inc(len(rows))
        self._costmodel_version.set(model.version)
        with self._refit_lock:
            if model.version != v0:         # regime change: track closely
                self._refit_interval = self.refit_every
            else:                           # converged: back off
                self._refit_interval = min(
                    self._refit_interval * 2,
                    self.refit_every * self.REFIT_BACKOFF_MAX)
        if model.path:
            try:
                model.save()
            except OSError:
                pass  # persistence is best-effort; serving keeps going

    def _run_staged(self, state: _VersionState,
                    lw: buildermod.SharedLowering):
        """Standalone (jit-staged when possible) execution of one plan.
        The staged compile caches live on the shared ``PhysicalPlan``, so
        execution is serialized per plan object across worker threads."""
        ex = PlanExecutor(state.env, mesh=self.session.mesh,
                          metrics=self.metrics)
        with self._lock:
            lock = state.plan_locks.setdefault(id(lw.plan),
                                               threading.Lock())
        with lock:
            out = ex.run(lw.plan)
        return out, ex

    # -- introspection --------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Stats snapshot: the legacy flat counter keys (now views over
        the metrics registry), the shared result-cache stats read
        atomically under that cache's lock, and serve-tier latency /
        queue-wait histogram summaries (p50/p90/p99 from buckets)."""
        out: Dict[str, object] = {
            name: c.value for name, c in self._counters.items()}
        out["arena_nodes"] = int(self._arena_nodes.value)
        out["result_cache"] = self._results.stats_snapshot()
        out["latency"] = self._latency.snapshot()
        out["queue_wait"] = self._queue_wait.snapshot()
        return out
