"""Multi-query serving engine: one session, thousands of concurrent queries.

The paper's premise is relational query processing as a *service* over big
matrix data; its Spark prototype amortizes optimization across a query
stream. This module is that serving tier for the jax engine:

* ``submit()`` accepts a stream of logical plans (``Expr`` or ``Matrix``)
  from many clients/tenants and returns a ``Ticket`` (an async handle);
  worker threads drain the queue in batches.
* **Cross-query CSE** — all queries over one catalog version lower into a
  single shared hash-consing arena (``plan.builder.SharedBuildState``):
  a subplan any earlier query lowered resolves to the same shared node
  id, and a shared LRU of materialized node results
  (``core.plancache.VersionedLRU``) turns that structural sharing into
  *execution* sharing — overlapping pipelines compute each shared
  subexpression once per catalog version. A whole-query repeat is a root
  hit and returns without touching the evaluator.
* **Shared optimizer state** — optimize results, the memo search's
  physical-cost cache and the catalog ``Leaves`` view are shared per
  catalog version, so overlapping queries cost each shared candidate
  subexpression once (``core.optimizer.optimize(cost_cache=...,
  leaves=...)``).
* **Batched leaf scans** — before a drained batch executes, the distinct
  leaves referenced by the whole batch are materialized once each into
  the shared result cache (one scan per leaf per batch, not per query).
* **Versioned caches** — every shared structure is keyed by the catalog
  version (bumped by ``Session.load``): a leaf rebind retires the old
  arena/results atomically for *new* queries while in-flight queries keep
  the version they started against. Invariant: every cache keyed on
  data-dependent annotations carries the catalog version.
* **Admission control** — a bounded queue plus per-tenant in-flight
  quotas reject excess load at submit time (``AdmissionError``), and
  per-tenant result-cache budgets stop one tenant's churn from flushing
  another's hot entries.

``cse=False`` disables the shared result cache and the arena reuse, and
executes each query standalone through the session's (jit-staged) path —
the baseline the serving benchmark compares against.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional

from repro.core import optimizer as optmod
from repro.core.expr import Expr, signature
from repro.core.plancache import VersionedLRU
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TRACER
from repro.plan import builder as buildermod
from repro.plan.executor import PlanExecutor
from repro.plan import ops as P
from repro.runtime import faults
from repro.runtime.fault_tolerance import (
    FaultCoordinator, HeartbeatMonitor, NodeState,
)
from repro.runtime.straggler import StragglerDetector


class AdmissionError(RuntimeError):
    """Submit rejected by admission control (queue full / tenant over
    budget). Clients are expected to back off and retry."""


class DeadlineExceeded(TimeoutError):
    """A ticket blew its ``deadline_s`` budget at a cooperative
    cancellation checkpoint (plan / prewarm / execute boundaries). The
    query is finished with this error instead of burning more engine
    time on a result the client has stopped waiting for."""


_UNSET = object()


class Ticket:
    """Async handle for one submitted query."""

    def __init__(self, query: Expr, tenant: str,
                 deadline_s: Optional[float] = None,
                 default_timeout: Optional[float] = None):
        self.query = query
        self.tenant = tenant
        self.submitted_at = time.perf_counter()
        self.deadline_s = deadline_s
        self.deadline = (None if deadline_s is None
                         else self.submitted_at + deadline_s)
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.reused_nodes = 0        # node results served from the shared LRU
        self.evaluated_nodes = 0
        self.trace = None            # obs.trace.Trace when sampled at submit
        self.opt = None              # OptimizeResult (predicted nnz → ledger)
        self._default_timeout = default_timeout
        self._done = threading.Event()
        self._finish_guard = threading.Lock()
        self._result = None
        self._error: Optional[BaseException] = None

    @property
    def trace_id(self) -> Optional[str]:
        return self.trace.trace_id if self.trace is not None else None

    # -- worker side ----------------------------------------------------------
    def _finish(self, result=None,
                error: Optional[BaseException] = None) -> bool:
        """Record the outcome exactly once. Returns False when the
        ticket was already finished — crash containment means several
        layers (per-ticket, batch-level, worker-exit, supervisor) may
        legitimately race to finish the same ticket, and only the first
        may count."""
        with self._finish_guard:
            if self._done.is_set():
                return False
            self._result, self._error = result, error
            self.finished_at = time.perf_counter()
            self._done.set()
            return True

    # -- client side ----------------------------------------------------------
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout=_UNSET):
        """Wait for the outcome. With no ``timeout`` argument the
        engine's ``default_timeout_s`` applies (pass ``timeout=None``
        explicitly to wait forever)."""
        t = self._default_timeout if timeout is _UNSET else timeout
        if not self._done.wait(t):
            raise TimeoutError(
                f"query still in flight after {t}s "
                f"(tenant={self.tenant!r}, trace_id={self.trace_id})")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency(self) -> float:
        """Submit→finish wall seconds (meaningful once ``done()``)."""
        return (self.finished_at or time.perf_counter()) - self.submitted_at


@dataclasses.dataclass
class _VersionState:
    """All cross-query shared state for one (catalog version × settings):
    the hash-consing arena, an immutable catalog snapshot, per-version
    optimizer caches, and the extracted-plan cache. Retired wholesale when
    the catalog version moves on (old instances keep serving their
    in-flight queries until unreferenced)."""

    key: tuple
    env: Dict                       # catalog snapshot (name → BlockMatrix)
    shared: buildermod.SharedBuildState
    leaves: object                  # plan.masks.Leaves over the snapshot
    cost_cache: Dict = dataclasses.field(default_factory=dict)
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    opt_cache: Optional[VersionedLRU] = None
    plans: Optional[VersionedLRU] = None       # optimized expr → SharedLowering
    plan_locks: Dict[int, threading.Lock] = \
        dataclasses.field(default_factory=dict)


class _NodeCache:
    """Adapter from the executor's ``get(plan, node)/put`` seam to the
    engine's shared result LRU, keyed by (version-state key, shared node
    id) and attributed to the submitting tenant for budget accounting."""

    def __init__(self, results: VersionedLRU, state_key: tuple, tenant: str):
        self._results = results
        self._state_key = state_key
        self._tenant = tenant

    def get(self, plan: P.PhysicalPlan, node: P.PhysicalNode):
        return self._results.get((self._state_key,
                                  node.meta.get("shared_id", node.op_id)))

    def put(self, plan: P.PhysicalPlan, node: P.PhysicalNode, result):
        self._results.put(
            (self._state_key, node.meta.get("shared_id", node.op_id)),
            result, tenant=self._tenant)


class ServeEngine:
    """Serving front end over one ``Session`` (see module docstring).

    Parameters
    ----------
    n_threads: worker threads draining the submit queue.
    max_queue: admission bound on queued tickets (global).
    tenant_max_inflight: admission bound on queued+running per tenant.
    cse: enable the cross-query shared arena + result cache.
    result_entries / tenant_result_budget: shared result LRU capacity and
        the per-tenant entry budget within it.
    batch_max: tickets drained per worker wakeup (the leaf-scan batching
        window).
    """

    # snapshot() compatibility keys, all registry-backed (``serve_<name>``)
    _COUNTERS = (
        "submitted", "completed", "errors",
        "rejected_queue", "rejected_tenant",
        "root_hits", "node_reuses", "node_evals",
        "inter_query_cse_nodes",
        "leaf_scans", "leaf_refs", "batches",
        "refits", "refit_rows",
        # robustness tier (PR 9): every degradation is counted
        "worker_crashes", "worker_restarts", "batch_failures",
        "prewarm_failures", "deadline_exceeded",
        "exec_retries", "degraded_eager",
        "ledger_errors", "refit_crashes", "stragglers_suspected",
        # kernel tier (PR 10): dispatches served from the fleet-shared
        # autotune artifact without a single tuning trial
        "autotune_warm_hits",
    )

    # errors the staged-execution retry loop must NOT retry: they are
    # deterministic (config / cancellation), not transient
    _NON_RETRYABLE = (DeadlineExceeded, AdmissionError, TypeError, KeyError)

    def __init__(self, session, *, n_threads: int = 2, max_queue: int = 1024,
                 tenant_max_inflight: Optional[int] = None, cse: bool = True,
                 result_entries: int = 1024,
                 tenant_result_budget: Optional[int] = None,
                 plan_entries: int = 128, opt_entries: int = 256,
                 batch_max: int = 32, keep_versions: int = 2,
                 registry: Optional[MetricsRegistry] = None,
                 trace_sample: Optional[float] = None,
                 ledger=None, ledger_root_hits: bool = False,
                 measure_comm: bool = False,
                 refit_every: Optional[int] = None,
                 default_timeout_s: Optional[float] = 300.0,
                 deadline_s: Optional[float] = None,
                 exec_retries: int = 2, retry_backoff_s: float = 0.005,
                 suspect_after_s: float = 10.0, fail_after_s: float = 30.0,
                 supervise_every_s: float = 0.5):
        self.session = session
        self.cse = cse
        self.max_queue = max_queue
        self.tenant_max_inflight = tenant_max_inflight
        self.batch_max = batch_max
        self._plan_entries = plan_entries
        self._opt_entries = opt_entries
        # per-engine registry by default: tests assert exact counter
        # values per engine; pass ``obs.metrics.REGISTRY`` to aggregate
        # process-wide instead
        self.metrics = registry if registry is not None else MetricsRegistry()
        # engine-level sampling override: None defers to the global
        # tracer's rate (REPRO_TRACE_SAMPLE); a float forces this
        # engine's own deterministic 1-in-N choice
        self.trace_sample = trace_sample
        self._trace_seq = 0
        # optional obs.ledger.CostLedger: one predicted-vs-actual row per
        # executed plan; measure_comm additionally compiles the staged
        # SPMD program for HLO-measured collective bytes (mesh runs only).
        # Root hits execute nothing (the row would record a cache lookup,
        # useless for cost-model re-fitting) so they are skipped unless
        # ledger_root_hits is set — this keeps the ledger off the
        # hottest serving path.
        self.ledger = ledger
        self.ledger_root_hits = ledger_root_hits
        self.measure_comm = measure_comm
        # online calibration: with a ledger AND a session cost model,
        # every ``refit_every`` executed (ledgered) plans a background
        # daemon thread re-fits the model from the accumulated rows. A
        # drift-exceeding fit bumps ``cost_model.version``, which is
        # part of the state key below — new queries admit the refreshed
        # coefficients while in-flight queries keep the version-state
        # they started against (the same retire machinery a catalog
        # rebind uses). The trigger interval backs off exponentially
        # while fits keep converging (no version bump) and snaps back
        # to ``refit_every`` on a bump: a converged model stops paying
        # fit CPU against the serving threads, a regime change is
        # tracked closely again.
        self.refit_every = refit_every
        self._refit_rows_seen = 0
        self._refit_interval = refit_every
        self._refit_last_at = 0
        self._refit_lock = threading.Lock()
        self._refit_thread: Optional[threading.Thread] = None
        self._results = VersionedLRU(result_entries,
                                     tenant_budget=tenant_result_budget,
                                     name="results", registry=self.metrics)
        self._counters = {name: self.metrics.counter("serve_" + name)
                          for name in self._COUNTERS}
        self._arena_nodes = self.metrics.gauge("serve_arena_nodes")
        self._costmodel_version = self.metrics.gauge(
            "serve_costmodel_version")
        self._latency = self.metrics.histogram("serve_latency_s")
        self._queue_wait = self.metrics.histogram("serve_queue_wait_s")
        self._states: "deque[_VersionState]" = deque(maxlen=keep_versions)
        self._queue: "deque[Ticket]" = deque()
        self._inflight: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._stop = False
        # degradation policy knobs (see docs/robustness.md)
        self.default_timeout_s = default_timeout_s
        self.deadline_s = deadline_s
        self.exec_retries = exec_retries
        self.retry_backoff_s = retry_backoff_s
        # worker supervision: every worker is a node in the seed
        # HeartbeatMonitor / FaultCoordinator (runtime.fault_tolerance);
        # workers beat per batch and per ticket, a dead thread is
        # force-failed immediately, and the coordinator's replace policy
        # names the replacement worker the supervisor spawns. The
        # straggler detector is fed per-ticket worker wall times and
        # hands persistent outliers to the monitor as SUSPECT.
        self._ft_lock = threading.Lock()
        worker_ids = [f"w{i}" for i in range(n_threads)]
        self._monitor = HeartbeatMonitor(
            worker_ids, suspect_after=suspect_after_s,
            fail_after=fail_after_s)
        self._coord = FaultCoordinator(self._monitor, reserves=[],
                                       min_world=1)
        self._straggler = StragglerDetector(list(worker_ids), window=16)
        self._next_worker = n_threads
        self._heartbeat_s = min(0.2, supervise_every_s)
        # warm-start the kernel autotuner from the fleet artifact before
        # any worker dispatches: buckets the artifact covers skip their
        # tuning trials entirely, and the warm-hit delta is mirrored into
        # ``serve_autotune_warm_hits`` as tickets complete
        from repro.kernels import autotune
        autotune.load_cache()
        self._autotune_warm_seen = autotune.tune_stats()["warm_hits"]
        self._worker_batches: Dict[str, List[Ticket]] = {}
        self._workers: Dict[str, threading.Thread] = {}
        for wid in worker_ids:
            t = threading.Thread(target=self._worker_loop, args=(wid,),
                                 daemon=True, name=f"serve-worker-{wid}")
            self._workers[wid] = t
            t.start()
        self._supervisor_stop = threading.Event()
        self._supervisor = threading.Thread(
            target=self._supervise_loop, args=(supervise_every_s,),
            daemon=True, name="serve-supervisor")
        self._supervisor.start()

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        with self._lock:
            self._stop = True
            self._work.notify_all()
        self._supervisor_stop.set()
        self._supervisor.join(timeout=10.0)
        with self._lock:
            threads = list(self._workers.values())
        for t in threads:
            # a genuinely hung worker cannot be joined — bounded wait so
            # close() never inherits the hang it exists to contain
            t.join(timeout=10.0)

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- client API -----------------------------------------------------------
    def submit(self, query, tenant: str = "default",
               deadline_s: Optional[float] = None) -> Ticket:
        """Enqueue one logical plan (an ``Expr`` or a ``core.api.Matrix``);
        raises ``AdmissionError`` when the queue or the tenant budget is
        full. ``deadline_s`` (default: the engine's ``deadline_s``)
        bounds queue wait + execution: past it, the next cooperative
        checkpoint finishes the ticket with ``DeadlineExceeded``."""
        expr = query.plan if hasattr(query, "plan") else query
        if not isinstance(expr, Expr):
            raise TypeError(f"not a logical plan: {type(query)}")
        ticket = Ticket(
            expr, tenant,
            deadline_s=self.deadline_s if deadline_s is None else deadline_s,
            default_timeout=self.default_timeout_s)
        with self._lock:
            if self._stop:
                raise RuntimeError("engine is closed")
            if len(self._queue) >= self.max_queue:
                self._counters["rejected_queue"].inc()
                raise AdmissionError(
                    f"queue full ({self.max_queue} tickets)")
            if (self.tenant_max_inflight is not None
                    and self._inflight.get(tenant, 0)
                    >= self.tenant_max_inflight):
                self._counters["rejected_tenant"].inc()
                raise AdmissionError(
                    f"tenant {tenant!r} over budget "
                    f"({self.tenant_max_inflight} in flight)")
            self._inflight[tenant] = self._inflight.get(tenant, 0) + 1
            self._counters["submitted"].inc()
            sample = self._sample_locked()
            self._queue.append(ticket)
            self._work.notify()
        # trace starts at submit (client thread) and is *activated* on
        # whichever worker thread executes the ticket — queue wait is the
        # gap between the two
        ticket.trace = TRACER.start("query", sample=sample, tenant=tenant,
                                    query=signature(expr))
        return ticket

    def _sample_locked(self) -> Optional[bool]:
        """Engine-level trace sampling decision (``self._lock`` held).
        None → defer to the global tracer's rate."""
        r = self.trace_sample
        if r is None:
            return None
        if r <= 0.0:
            return False
        if r >= 1.0:
            return True
        period = max(1, round(1.0 / r))
        self._trace_seq += 1
        return self._trace_seq % period == 0

    def run(self, query, tenant: str = "default", timeout=_UNSET,
            deadline_s: Optional[float] = None):
        """Submit and wait (the synchronous convenience path)."""
        return self.submit(query, tenant=tenant,
                           deadline_s=deadline_s).result(timeout)

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every submitted ticket has finished."""
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            with self._lock:
                if not self._queue and not any(self._inflight.values()):
                    return
            time.sleep(0.001)
        raise TimeoutError("engine did not drain")

    # -- version-state management ---------------------------------------------
    def _state_key(self, version: int) -> tuple:
        import os
        s = self.session
        return (version, s.mode, s.block_size, s.use_bloom, s.n_workers,
                s._mesh_key(), os.environ.get("REPRO_KERNEL_BACKEND"),
                s._costmodel_key())

    def _current_state(self) -> _VersionState:
        """The shared state for the catalog as of *now*. The version is
        read on both sides of the snapshot so a concurrent ``load`` can
        never produce a state whose snapshot mixes versions."""
        from repro.plan import masks as masksmod
        s = self.session
        while True:
            v = s._env_version
            key = self._state_key(v)
            with self._lock:
                for st in self._states:
                    if st.key == key:
                        return st
            env = dict(s.env)
            if s._env_version != v:
                continue                      # rebind raced the snapshot
            st = _VersionState(
                key=key, env=env,
                shared=buildermod.SharedBuildState(
                    mode=s.mode, block_size=s.block_size,
                    use_bloom=s.use_bloom, n_workers=s.workers),
                leaves=masksmod.Leaves(env, s.block_size),
                opt_cache=VersionedLRU(self._opt_entries),
                plans=VersionedLRU(self._plan_entries))
            with self._lock:
                for other in self._states:
                    if other.key == key:      # another thread won the race
                        return other
                self._states.append(st)
            return st

    # -- worker side ----------------------------------------------------------
    def _finish_ticket(self, ticket: Ticket, result=None,
                       error: Optional[BaseException] = None) -> None:
        """The single completion site: every ticket — success, plan
        failure, execution failure, deadline, worker crash — ends here
        EXACTLY once (``Ticket._finish`` is first-wins), so
        ``completed``/``errors``, the latency histogram and the
        per-tenant in-flight accounting can never drift from the ticket
        stream even when crash containment races normal completion."""
        if not ticket._finish(result=result, error=error):
            return
        self._counters["errors" if error is not None
                       else "completed"].inc()
        self._sync_autotune_metric()
        if isinstance(error, DeadlineExceeded):
            self._counters["deadline_exceeded"].inc()
        self._latency.observe(ticket.latency)
        with self._lock:
            n = self._inflight.get(ticket.tenant, 0) - 1
            if n > 0:
                self._inflight[ticket.tenant] = n
            else:
                self._inflight.pop(ticket.tenant, None)
        if ticket.trace is not None:
            ticket.trace.finish()

    def _check_deadline(self, ticket: Ticket, phase: str) -> None:
        """Cooperative cancellation checkpoint (plan / prewarm / execute
        boundaries)."""
        if (ticket.deadline is not None
                and time.perf_counter() > ticket.deadline):
            raise DeadlineExceeded(
                f"deadline of {ticket.deadline_s}s exceeded at {phase!r} "
                f"(tenant={ticket.tenant!r}, trace_id={ticket.trace_id})")

    def _beat(self, wid: str) -> bool:
        """Heartbeat ``wid`` into the monitor; False when the restart
        policy has retired this worker (it must exit its loop)."""
        with self._ft_lock:
            if wid not in self._monitor.nodes:
                return False
            self._monitor.beat(wid)
        return True

    def _worker_loop(self, wid: str) -> None:
        """One worker thread: drain batches until stopped, retired, or
        killed. ANY abnormal exit flows through ``_worker_exit``, which
        finishes the in-flight batch with the error and hands the crash
        to the coordinator-driven restart policy — a worker death can
        strand neither its tickets nor its queue slot."""
        err: Optional[BaseException] = None
        try:
            while True:
                batch = self._next_batch(wid)
                if batch is None:
                    return
                if batch:
                    self._process_batch(wid, batch)
        except BaseException as e:
            err = e
        finally:
            self._worker_exit(wid, err)

    def _next_batch(self, wid: str) -> Optional[List[Ticket]]:
        """One drain attempt: ``None`` → exit (stop/retired), ``[]`` →
        idle wakeup (beat again, re-check). Idle waits are bounded by
        the heartbeat interval so a quiet worker still beats."""
        if not self._beat(wid):
            return None
        with self._lock:
            if self._stop and not self._queue:
                return None
            if not self._queue:
                self._work.wait(timeout=self._heartbeat_s)
                return []
            batch: List[Ticket] = []
            while self._queue and len(batch) < self.batch_max:
                batch.append(self._queue.popleft())
            self._counters["batches"].inc()
            self._worker_batches[wid] = batch
        return batch

    def _process_batch(self, wid: str, batch: List[Ticket]) -> None:
        """Plan, prewarm and execute one batch. Failure containment, in
        order of blast radius: per-ticket failures finish that ticket;
        prewarm failures degrade the batch to un-prewarmed execution;
        batch-level failures (version snapshot, bookkeeping) finish
        every ticket in the batch with the error — the regression this
        pins is an exception between dequeue and the per-ticket loop
        stranding a whole batch of clients in ``result()``. Worker-kill
        faults (``BaseException``) pass through to ``_worker_exit``."""
        t_batch0 = time.perf_counter()
        try:
            faults.check("worker", worker=wid)
            state = self._current_state()
            lowered = [self._plan_ticket(state, t) for t in batch]
            if self.cse:
                t0 = time.perf_counter()
                try:
                    faults.check("prewarm", worker=wid)
                    self._prewarm_leaves(state, [p for p in lowered
                                                 if p is not None])
                except Exception:
                    # contained per-batch: leaves will materialize
                    # per-query through the result cache instead
                    self._counters["prewarm_failures"].inc()
                t1 = time.perf_counter()
                # batch-level phase, attributed to every traced ticket
                for ticket in batch:
                    if ticket.trace is not None:
                        with TRACER.activate(ticket.trace):
                            TRACER.add_event("batch_prewarm", t0, t1,
                                             batch=len(batch))
            for ticket, lw in zip(batch, lowered):
                if lw is None:
                    continue        # already finished in _plan_ticket
                self._beat(wid)     # long batches must not look hung
                try:
                    self._check_deadline(ticket, "execute")
                    with TRACER.activate(ticket.trace):
                        self._execute(state, ticket, lw)
                except Exception as e:  # propagate to the client
                    self._finish_ticket(ticket, error=e)
        except BaseException as e:
            if not isinstance(e, Exception):
                raise               # worker-killing: _worker_exit cleans up
            self._counters["batch_failures"].inc()
            for t in batch:
                self._finish_ticket(t, error=e)
        self._worker_batches.pop(wid, None)
        with self._ft_lock:
            self._straggler.record(
                wid, (time.perf_counter() - t_batch0) / len(batch))

    def _worker_exit(self, wid: str, err: Optional[BaseException]) -> None:
        """Last act of a worker thread (normal exit, retirement, or
        death): finish any batch it still held, then — for a crash —
        report the node failed and run the restart policy inline so
        recovery does not wait for the next supervisor sweep."""
        batch = self._worker_batches.pop(wid, None)
        if batch:
            e = (err if isinstance(err, Exception)
                 else RuntimeError(f"serve worker {wid} died: {err!r}"))
            for t in batch:
                self._finish_ticket(t, error=e)
        if err is None or self._stop:
            return
        self._counters["worker_crashes"].inc()
        with self._ft_lock:
            self._monitor.force_fail(wid)
        self._supervise_once()

    # -- supervision ----------------------------------------------------------
    def _supervise_loop(self, every_s: float) -> None:
        while not self._supervisor_stop.wait(every_s):
            try:
                self._supervise_once()
            except Exception:       # supervision must outlive its bugs
                self.metrics.counter("serve_supervisor_errors").inc()

    def _supervise_once(self) -> None:
        """One sweep of the restart policy: force-fail dead threads,
        SUSPECT/FAILED transitions from heartbeats, straggler hand-off,
        and coordinator-planned replacement of FAILED workers."""
        to_spawn: List[tuple] = []
        with self._ft_lock:
            for wid, th in list(self._workers.items()):
                # a dead thread cannot beat again: fail it immediately
                # rather than waiting out the fail_after window
                if not th.is_alive() and wid in self._monitor.nodes:
                    self._monitor.force_fail(wid)
            self._monitor.sweep()
            failed = [n for n, i in self._monitor.nodes.items()
                      if i.state is NodeState.FAILED]
            if failed:
                # top up the reserve pool so the policy always replaces
                # (a serving engine shrinks only when told to)
                while len(self._coord.reserves) < len(failed):
                    self._coord.reserves.append(f"w{self._next_worker}")
                    self._next_worker += 1
                plan = self._coord.plan()
                if plan.action == "replace":
                    for old, new in zip(plan.failed, plan.replacements):
                        self._straggler.drop_host(old)
                        self._straggler.add_host(new)
                        to_spawn.append((old, new))
            else:
                # persistent latency outliers become SUSPECT: a later
                # hard failure is pre-diagnosed, and the transition is
                # visible in the snapshot before anything breaks
                rep = self._straggler.detect()
                for slow in rep.slow_hosts:
                    info = self._monitor.nodes.get(slow)
                    if info is not None and \
                            info.state is NodeState.HEALTHY:
                        self._monitor.suspect(slow)
                        self._counters["stragglers_suspected"].inc()
        for old, new in to_spawn:
            # a hung (not dead) worker may still hold a batch; its
            # clients get an error now instead of a silent hang. If the
            # hung thread later resumes, every completion path is
            # idempotent and its next beat tells it to exit.
            batch = self._worker_batches.pop(old, None)
            if batch:
                e = RuntimeError(
                    f"serve worker {old} removed by restart policy")
                for t in batch:
                    self._finish_ticket(t, error=e)
            with self._lock:
                if self._stop:
                    continue
                th = threading.Thread(
                    target=self._worker_loop, args=(new,),
                    daemon=True, name=f"serve-worker-{new}")
                self._workers.pop(old, None)
                self._workers[new] = th
                th.start()
            self._counters["worker_restarts"].inc()

    def _plan_ticket(self, state: _VersionState, ticket: Ticket
                     ) -> Optional[buildermod.SharedLowering]:
        """Optimize + lower one ticket against the shared per-version
        state; on failure the ticket is finished with the error and None
        is returned."""
        s = self.session
        try:
            ticket.started_at = time.perf_counter()
            self._queue_wait.observe(ticket.started_at
                                     - ticket.submitted_at)
            self._check_deadline(ticket, "plan")
            with TRACER.activate(ticket.trace):
                TRACER.add_event("queue_wait", ticket.submitted_at,
                                 ticket.started_at)
                TRACER.annotate(admitted_version=state.key[0])
                opt = state.opt_cache.get_or_create(
                    (ticket.query, s.search),
                    lambda: optmod.optimize(
                        ticket.query, search=s.search, session=s,
                        cost_cache=state.cost_cache, leaves=state.leaves),
                    tenant=ticket.tenant)
                ticket.opt = opt
                if not self.cse:
                    # standalone lowering: no shared arena, fresh/ per-expr
                    # plan via the session cache (jit-staged execution path)
                    plan = state.plans.get_or_create(
                        opt.plan, lambda: buildermod.build_plan(
                            opt.plan, mode=s.mode, block_size=s.block_size,
                            use_bloom=s.use_bloom, n_workers=s.workers,
                            cost_model=s.cost_model),
                        tenant=ticket.tenant)
                    return buildermod.SharedLowering(
                        plan=plan, root_shared_id=-1, reused_nodes=0,
                        new_nodes=plan.n_nodes)
                def _lower():
                    with state.lock:
                        lw = buildermod.lower_shared(
                            state.shared, opt.plan,
                            cost_model=s.cost_model)
                    self._counters["inter_query_cse_nodes"].inc(
                        lw.reused_nodes)
                    self._arena_nodes.set(len(state.shared.nodes))
                    return lw
                return state.plans.get_or_create(opt.plan, _lower,
                                                 tenant=ticket.tenant)
        except Exception as e:      # kills (BaseException) escape to
            self._finish_ticket(ticket, error=e)      # _worker_exit
            return None

    def _prewarm_leaves(self, state: _VersionState,
                        lowered: List[buildermod.SharedLowering]) -> None:
        """Batched leaf scans: materialize each distinct leaf the batch
        references once into the shared result cache."""
        from repro.core.executor import leaf_value
        seen = set()
        for lw in lowered:
            for node in lw.plan.nodes:
                if node.kind != P.LEAF:
                    continue
                key = (state.key, node.meta["shared_id"])
                self._counters["leaf_refs"].inc()
                if key in seen or self._results.get(key) is not None:
                    continue
                seen.add(key)
                val = leaf_value(node.expr, state.env, state.shared.block_size)
                self._results.put(key, val)
                self._counters["leaf_scans"].inc()

    # Minimum fraction of a plan's estimated flops that cached subresults
    # must cover before the engine prefers per-node eager reuse over the
    # jit-staged path (eager pays per-node dispatch overhead; staged pays
    # recomputing the overlap).
    EAGER_REUSE_MIN_COVERAGE = 0.5

    def _cse_coverage(self, state: _VersionState,
                      plan: P.PhysicalPlan) -> float:
        """Fraction of ``plan``'s estimated flops already materialized in
        the shared result cache: a cached node covers its whole subtree
        (evaluation stops there). Leaf hits contribute nothing — leaves
        carry no flops, and re-scanning one is cheap."""
        cached = {
            n.op_id for n in plan.nodes
            if n.kind != P.LEAF
            and (state.key, n.meta["shared_id"]) in self._results}
        if not cached:
            return 0.0
        need = set()
        stack = [plan.root]
        while stack:
            i = stack.pop()
            if i in need or i in cached:
                continue
            need.add(i)
            stack.extend(plan.node(i).children)
        total = plan.est_flops
        if total <= 0:
            return 1.0
        return 1.0 - sum(plan.node(i).est_flops for i in need) / total

    def _execute(self, state: _VersionState, ticket: Ticket,
                 lw: buildermod.SharedLowering) -> None:
        import jax
        t0 = time.perf_counter()
        exec_path = None
        ex = None
        if self.cse:
            root_key = (state.key,
                        lw.plan.node(lw.plan.root).meta["shared_id"])
            hit = self._results.get(root_key)
            if hit is not None:
                self._counters["root_hits"].inc()
                ticket.reused_nodes = lw.plan.n_nodes
                if self.ledger_root_hits:
                    self._ledger_row(state, ticket, lw.plan, "root_hit",
                                     time.perf_counter() - t0, 0.0)
                self._finish_ticket(ticket, result=hit)
                return
            if (self._cse_coverage(state, lw.plan)
                    >= self.EAGER_REUSE_MIN_COVERAGE):
                # substantial overlap with earlier queries: evaluate
                # eagerly, reusing every shared node result and publishing
                # the new ones (inter-query subexpression sharing)
                ex = PlanExecutor(
                    state.env, metrics=self.metrics,
                    node_cache=_NodeCache(self._results, state.key,
                                          ticket.tenant))
                out = ex.run(lw.plan)
                exec_path = "eager_reuse"
            else:
                # cold pipeline: run the fast (jit-staged) path once and
                # publish its root, which seeds subplan reuse for every
                # later query that embeds this one
                out, ex = self._run_staged(state, lw)
                self._results.put(root_key, out, tenant=ticket.tenant)
        else:
            out, ex = self._run_staged(state, lw)
        value = getattr(out, "value", out)
        try:
            jax.block_until_ready(value)       # latency = results on host
        except Exception:
            pass                               # host-side results (COO etc.)
        ticket.reused_nodes = ex.stats["node_reuses"]
        ticket.evaluated_nodes = ex.stats["node_evals"]
        self._counters["node_reuses"].inc(ex.stats["node_reuses"])
        self._counters["node_evals"].inc(ex.stats["node_evals"])
        if exec_path is None:
            from repro.obs.ledger import exec_path_of
            exec_path = exec_path_of(ex.stats)
        self._ledger_row(state, ticket, lw.plan, exec_path,
                         time.perf_counter() - t0,
                         ex.timings["compile_s"],
                         overflow=ex.stats["sparse_overflows"] > 0)
        self._finish_ticket(ticket, result=out)

    def _ledger_row(self, state: _VersionState, ticket: Ticket, plan,
                    exec_path: str, wall_s: float, compile_s: float,
                    overflow: bool = False) -> None:
        if self.ledger is None:
            return
        try:
            measured_comm = None
            if self.measure_comm:
                if self.session.mesh is not None:
                    from repro.obs.ledger import measured_comm_bytes
                    measured_comm = measured_comm_bytes(plan, state.env,
                                                        self.session.mesh)
                else:
                    # single device: no interconnect, so the measured
                    # collective traffic is exactly zero — recording it
                    # keeps the predicted/measured comm gate meaningful
                    # off-mesh (predicted must also be 0 for ratio 1.0)
                    measured_comm = 0
            self.ledger.record(
                query=signature(ticket.query), plan=plan,
                exec_path=exec_path, wall_s=wall_s, compile_s=compile_s,
                measured_comm=measured_comm, overflow=overflow,
                opt=ticket.opt, trace_id=ticket.trace_id,
                tenant=ticket.tenant)
        except Exception:
            # isolation contract: the audit row is subordinate to the
            # query — a ledger failure (including an injected
            # ``ledger_io`` fault that escaped drop-and-count, or a
            # comm-measurement crash) is counted, never propagated
            self._counters["ledger_errors"].inc()
            return
        if exec_path != "root_hit":
            self._maybe_refit()

    # -- online calibration ---------------------------------------------------

    # Each background refit fits from at most this many of the ledger's
    # most recent rows: bounded work per fit (a full-history refit would
    # grow O(n) per trigger, O(n²) over a serving session) that also
    # weights the fit toward the current workload regime.
    REFIT_WINDOW_ROWS = 512

    # Convergence backoff cap: while successive fits stay within the
    # model's drift threshold (no version bump) the trigger interval
    # doubles per fit, up to refit_every * this factor.
    REFIT_BACKOFF_MAX = 32

    def _maybe_refit(self) -> None:
        """Count one executed (ledgered) plan; when the backoff interval
        has elapsed, kick a background refit of the session cost model
        from the tail window of the ledger's in-memory rows. The hot
        path pays one lock + counter — fitting happens off-thread, and
        at most one refit runs at a time (a still-running fit skips the
        trigger rather than queue)."""
        if (self.refit_every is None
                or getattr(self.session, "cost_model", None) is None):
            return
        with self._refit_lock:
            self._refit_rows_seen += 1
            if (self._refit_rows_seen - self._refit_last_at
                    < self._refit_interval):
                return
            if (self._refit_thread is not None
                    and self._refit_thread.is_alive()):
                return
            self._refit_last_at = self._refit_rows_seen
            rows = self.ledger.rows()[-self.REFIT_WINDOW_ROWS:]
            t = threading.Thread(target=self._refit, args=(rows,),
                                 daemon=True, name="serve-refit")
            self._refit_thread = t
            t.start()

    def _refit(self, rows) -> None:
        model = self.session.cost_model
        v0 = model.version
        try:
            faults.check("refit")
            ok = model.fit_from_rows(rows)
        except Exception:
            # a crashed refit thread must not take online calibration
            # down with it: count the crash and leave the trigger armed —
            # ``_maybe_refit`` sees the dead thread and relaunches at the
            # next interval
            self._counters["refit_crashes"].inc()
            with self._refit_lock:
                self._refit_last_at = (self._refit_rows_seen
                                       - self._refit_interval)
            return
        if not ok:
            return
        self._counters["refits"].inc()
        self._counters["refit_rows"].inc(len(rows))
        self._costmodel_version.set(model.version)
        with self._refit_lock:
            if model.version != v0:         # regime change: track closely
                self._refit_interval = self.refit_every
            else:                           # converged: back off
                self._refit_interval = min(
                    self._refit_interval * 2,
                    self.refit_every * self.REFIT_BACKOFF_MAX)
        if model.path:
            try:
                model.save()
            except OSError:
                pass  # persistence is best-effort; serving keeps going

    def _run_staged(self, state: _VersionState,
                    lw: buildermod.SharedLowering):
        """Standalone (jit-staged when possible) execution of one plan,
        hardened with the degradation ladder (docs/robustness.md):
        transient staged-path failures (a flaky staged compile, an
        injected ``execute`` fault) are retried with exponential backoff
        up to ``exec_retries`` times, then execution falls down to the
        per-node eager path (``stage_jit=False``) — semantically
        identical, slower, and immune to staging failures. Deterministic
        errors (``_NON_RETRYABLE``) propagate immediately.

        The staged compile caches live on the shared ``PhysicalPlan``, so
        execution is serialized per plan object across worker threads."""
        with self._lock:
            lock = state.plan_locks.setdefault(id(lw.plan),
                                               threading.Lock())
        for attempt in range(self.exec_retries + 1):
            ex = PlanExecutor(state.env, mesh=self.session.mesh,
                              metrics=self.metrics)
            try:
                with lock:
                    faults.check("execute", attempt=attempt)
                    out = ex.run(lw.plan)
                return out, ex
            except self._NON_RETRYABLE:
                raise
            except Exception:
                if attempt == self.exec_retries:
                    break           # ladder: degrade instead of raising
                self._counters["exec_retries"].inc()
                time.sleep(self.retry_backoff_s * (2 ** attempt))
        # bottom of the ladder: per-node eager execution never touches
        # the staged-compile seam; a failure here is genuine and
        # propagates to the client through per-ticket containment
        self._counters["degraded_eager"].inc()
        ex = PlanExecutor(state.env, stage_jit=False, metrics=self.metrics)
        with lock:
            out = ex.run(lw.plan)
        return out, ex

    # -- introspection --------------------------------------------------------
    def _sync_autotune_metric(self) -> None:
        """Mirror the autotuner's process-wide warm-hit count into this
        engine's registry as a delta (many engines may share the
        process; each only claims hits observed on its own watch)."""
        from repro.kernels import autotune
        seen = autotune.tune_stats()["warm_hits"]
        delta = seen - self._autotune_warm_seen
        if delta > 0:
            self._autotune_warm_seen = seen
            self._counters["autotune_warm_hits"].inc(delta)

    def snapshot(self) -> Dict[str, object]:
        """Stats snapshot: the legacy flat counter keys (now views over
        the metrics registry), the shared result-cache stats read
        atomically under that cache's lock, and serve-tier latency /
        queue-wait histogram summaries (p50/p90/p99 from buckets)."""
        self._sync_autotune_metric()
        out: Dict[str, object] = {
            name: c.value for name, c in self._counters.items()}
        out["arena_nodes"] = int(self._arena_nodes.value)
        out["result_cache"] = self._results.stats_snapshot()
        out["latency"] = self._latency.snapshot()
        out["queue_wait"] = self._queue_wait.snapshot()
        return out
