"""Ambient activation-sharding context.

Model code is pure; the launcher (dry-run / trainer) installs the mesh +
rules here and model layers call ``shard_act(x, *logical_axes)`` at
materialization points. Without an installed context the calls are no-ops
(single-device tests). This is what pins activations batch-sharded so the
GSPMD partitioner gathers WEIGHTS (FSDP) instead of replicating the batch.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.module import MeshRules, act_spec

_STATE = threading.local()


def current() -> Optional[Tuple[Mesh, MeshRules]]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: MeshRules):
    prev = current()
    _STATE.ctx = (mesh, rules)
    try:
        yield
    finally:
        _STATE.ctx = prev


def shard_act(x, *logical: Optional[str]):
    """Constrain an activation to the logical axes (no-op w/o context)."""
    ctx = current()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical) != x.ndim:
        return x
    spec = _divisible_spec(mesh, rules, logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _divisible_spec(mesh, rules, logical, shape) -> P:
    out = []
    used: set = set()
    for dim, lg in zip(shape, logical):
        axes = tuple(a for a in rules.mesh_axes_for(lg)
                     if a in mesh.shape and a not in used)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and size > 1 and dim % size == 0:
            out.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            out.append(None)
    return P(*out)
