"""Activation / input / cache partition specs over the production mesh."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.module import MeshRules


def _present(mesh: Mesh, axes: Tuple[str, ...]) -> Tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.shape)


def _size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _maybe(mesh: Mesh, axes: Tuple[str, ...], dim: int):
    axes = _present(mesh, axes)
    if axes and dim % _size(mesh, axes) == 0 and _size(mesh, axes) > 1:
        return axes if len(axes) > 1 else axes[0]
    return None


def input_partition_specs(mesh: Mesh, rules: MeshRules,
                          specs: Dict[str, jax.ShapeDtypeStruct]
                          ) -> Dict[str, P]:
    """Batch-shard every model input on its leading dim (pos scalar: repl)."""
    out = {}
    for name, s in specs.items():
        if not s.shape:
            out[name] = P()
            continue
        lead = _maybe(mesh, rules.batch, s.shape[0])
        out[name] = P(lead, *([None] * (len(s.shape) - 1)))
    return out


def cache_partition_specs(cfg: ModelConfig, mesh: Mesh, rules: MeshRules,
                          cache_tree) -> Any:
    """Decode-cache shardings by leaf role.

    Priority per leaf: batch dim → DP axes; heads/channels → tensor axis;
    when the batch is unshardable (e.g. long_500k B=1), the sequence dim of
    attention KV takes the DP axes instead (sequence-sharded cache).
    """
    def leaf_spec(path: Tuple, leaf) -> P:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        shape = leaf.shape
        batch_axes = _present(mesh, rules.batch)
        tensor_axes = _present(mesh, rules.tensor)
        b_sh = _maybe(mesh, batch_axes, shape[1]) if len(shape) > 1 else None
        if name in ("k", "v"):
            # [L, B, N, H, hd]
            seq_sh = None if b_sh is not None else _maybe(
                mesh, batch_axes, shape[2])
            h_sh = _maybe(mesh, tensor_axes, shape[3])
            return P(None, b_sh, seq_sh, h_sh, None)
        if name == "pos":
            seq_sh = None if b_sh is not None else _maybe(
                mesh, batch_axes, shape[2])
            return P(None, b_sh, seq_sh)
        if name == "conv":      # [L, B, K-1, d_in]
            return P(None, b_sh, None, _maybe(mesh, tensor_axes, shape[3]))
        if name == "h":         # [L, B, d_in, N]
            return P(None, b_sh, _maybe(mesh, tensor_axes, shape[2]), None)
        if name == "wkv":       # [L, B, H, hd, hd]
            return P(None, b_sh, _maybe(mesh, tensor_axes, shape[2]),
                     None, None)
        if name in ("shift_t", "shift_c"):  # [L, B, d]
            return P(None, b_sh, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
