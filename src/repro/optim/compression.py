"""Gradient compression: int8 quantization with error feedback.

Two layers:

* ``quantize``/``dequantize`` + ``ErrorFeedback`` — per-tensor max-abs int8
  quantization with a persistent residual (error-feedback) buffer; proven to
  preserve SGD/Adam convergence (Karimireddy et al., 2019).
* ``compressed_psum`` — a shard_map-compatible all-reduce that moves int8 on
  the wire: max-abs psum (f32 scalar per tensor) → int8 encode → int32-psum
  → rescale. Byte volume on the DP axis drops 4× vs f32 (2× vs bf16).

Under single-program jit the XLA autodiff already emits the DP reduction, so
the framework wires compression in at the explicit shard_map DP boundary
(``train.step`` with ``dp_shard_map=True``); with plain jit the quantize →
dequantize pair still runs (convergence-accurate simulation, no wire
savings) — both modes are tested for numerical equivalence bounds.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    q: jnp.ndarray       # int8 payload
    scale: jnp.ndarray   # f32 scalar


def quantize(x: jnp.ndarray) -> Quantized:
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return Quantized(q, scale)


def dequantize(qx: Quantized, dtype=jnp.float32) -> jnp.ndarray:
    return (qx.q.astype(jnp.float32) * qx.scale).astype(dtype)


class ErrorFeedback(NamedTuple):
    residual: Any  # pytree congruent with grads


def ef_init(grads_like) -> ErrorFeedback:
    return ErrorFeedback(jax.tree.map(jnp.zeros_like, grads_like))


def ef_compress(grads, ef: ErrorFeedback) -> Tuple[Any, ErrorFeedback]:
    """g_hat = Q(g + e);  e' = (g + e) - g_hat  (per tensor)."""
    def one(g, e):
        corrected = g + e
        qx = quantize(corrected)
        g_hat = dequantize(qx, g.dtype)
        return g_hat, corrected - g_hat

    flat = jax.tree.map(one, grads, ef.residual)
    g_hat = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return g_hat, ErrorFeedback(resid)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-on-the-wire psum for use inside shard_map (DP axis reduction).

    scale = psum-max of local amax (tiny f32 collective), then int8 encode,
    int32 psum (the big collective at 1/4 the f32 bytes), rescale.
    """
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total.astype(jnp.float32) * scale / n).astype(x.dtype)
