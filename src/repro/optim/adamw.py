"""AdamW with decoupled weight decay — sharded state, no optax dependency.

Optimizer state inherits each parameter's sharding (m/v are elementwise), so
under the production mesh the Adam moments are distributed exactly like the
FSDP×TP weights (DESIGN.md §5 memory budget).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    lr_schedule: str = "cosine"      # cosine | constant
    total_steps: int = 10_000

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def _lr_at(self, step: jnp.ndarray) -> jnp.ndarray:
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(1, self.warmup_steps))
        if self.lr_schedule == "cosine":
            t = jnp.clip((s - self.warmup_steps)
                         / max(1, self.total_steps - self.warmup_steps),
                         0.0, 1.0)
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        else:
            decay = 1.0
        return self.lr * warm * decay

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        count = state.count + 1
        lr = self._lr_at(count)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g,
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g),
                         state.v, grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c

        def upd(mm, vv, p):
            mhat = mm / bc1
            vhat = vv / bc2
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                step = step + self.weight_decay * p
            return p - lr * step

        new_params = jax.tree.map(upd, m, v, params)
        return new_params, AdamWState(count, m, v)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm
