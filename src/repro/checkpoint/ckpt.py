"""Checkpointing: content-checksummed shards, async save, elastic restore.

Layout:
    <dir>/step_<N>/
        manifest.json       # leaf paths, shapes, dtypes, checksums, step
        <leaf-hash>.npy     # one file per pytree leaf

Fault-tolerance properties (DESIGN.md §6):
  * atomic publish — shards land in a tmp dir, manifest written last, dir
    renamed; a crash mid-save never corrupts the latest checkpoint;
  * checksums (crc32 of raw bytes) verified on restore;
  * async save — a background thread serializes device arrays after they are
    fetched, so the train loop blocks only for the host transfer;
  * elastic restore — arrays are re-sharded onto whatever mesh the restart
    runs with (``jax.device_put`` against the new shardings), so a job can
    resume on a different device count after node failures.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = leaf
    return flat


def _unflatten_into(tree, flat: Dict[str, np.ndarray]):
    def rebuild(path, leaf):
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        return flat[key]

    return jax.tree_util.tree_map_with_path(rebuild, tree)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        t = threading.Thread(target=self._write, args=(step, host),
                             daemon=True)
        t.start()
        self._thread = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for key, arr in _flatten(host_tree).items():
            arr = np.asarray(arr)
            fname = f"{abs(hash(key)) & 0xFFFFFFFF:08x}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.available())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def available(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d[5:]))
        return sorted(out)

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None, verify: bool = True):
        """Restore into the structure of ``tree_like``; optionally re-shard
        (elastic restart onto a different mesh)."""
        steps = self.available()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        step = steps[-1] if step is None else step
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(d, meta["file"]))
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc32"]:
                    raise IOError(f"checksum mismatch for {key}")
            flat[key] = arr
        tree = _unflatten_into(tree_like, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, step
