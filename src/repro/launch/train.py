"""End-to-end training driver.

CPU-scale usage (reduced config, real training):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --batch 8 --seq 128

Production usage lowers the same step under the production mesh (the
dry-run path proves that lowering; this driver executes on whatever devices
exist). Integrates: MatRel data preprocessing, AdamW, grad accumulation,
optional int8 error-feedback compression, async checkpointing, heartbeat +
straggler monitoring.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, PrefetchLoader, \
    SyntheticCorpus, pack_batches
from repro.models import api as mapi
from repro.models.module import init_params
from repro.optim.adamw import AdamW
from repro.runtime.fault_tolerance import FaultCoordinator, HeartbeatMonitor
from repro.runtime.straggler import StragglerDetector
from repro.train.step import init_state, make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    print(f"[train] arch={cfg.arch_id} family={cfg.family} "
          f"layers={cfg.n_layers} d={cfg.d_model} devices="
          f"{len(jax.devices())}")

    # data: synthetic corpus → MatRel relational preprocessing → batches
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, n_docs=256,
                    doc_len=max(512, args.seq + 1), seed=args.seed)
    corpus = SyntheticCorpus(dc)
    train_matrix = corpus.preprocess()
    print(f"[data] corpus {corpus.matrix.shape} → cleaned+split "
          f"{train_matrix.shape} (MatRel σ_rows≠NULL + RID-range folds)")

    params = init_params(jax.random.key(args.seed), mapi.spec(cfg))
    opt = AdamW(lr=args.lr, total_steps=args.steps)
    state = init_state(params, opt, compress=args.compress)
    step_fn = jax.jit(make_train_step(cfg, opt, grad_accum=args.grad_accum,
                                      compress=args.compress),
                      donate_argnums=(0,))

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    hosts = [f"host{i}" for i in range(max(1, jax.process_count()))]
    monitor = HeartbeatMonitor(hosts)
    coordinator = FaultCoordinator(monitor, reserves=["reserve0"])
    straggler = StragglerDetector(hosts)

    def batches():
        while True:
            yield from pack_batches(train_matrix, dc)

    loader = PrefetchLoader(batches())
    it = iter(loader)
    losses = []
    t_start = time.time()
    for step in range(1, args.steps + 1):
        t0 = time.time()
        host_batch = next(it)
        if cfg.family == "vlm":
            host_batch = dict(
                host_batch,
                tokens=host_batch["tokens"][:, :-cfg.n_img_tokens]
                if host_batch["tokens"].shape[1] > cfg.n_img_tokens
                else host_batch["tokens"],
                img_embeds=np.zeros((args.batch, cfg.n_img_tokens,
                                     cfg.img_embed_dim), np.float32))
            host_batch["labels"] = np.pad(
                host_batch["labels"], ((0, 0), (cfg.n_img_tokens, 0)),
                constant_values=-100)[:, :host_batch["labels"].shape[1]
                                      + cfg.n_img_tokens]
        if cfg.family == "audio":
            host_batch = dict(host_batch, frames=np.random.default_rng(
                step).normal(size=(args.batch, args.seq, cfg.d_model)
                             ).astype(np.float32))
        batch = jax.tree.map(jnp.asarray, host_batch)
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        monitor.beat("host0")
        straggler.record("host0", dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == 1:
            print(f"[step {step:4d}] loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['acc']):.3f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"dt={dt*1e3:.0f}ms")
        if ckpt and step % args.ckpt_every == 0:
            ckpt.save(step, {"params": state.params,
                             "opt": state.opt._asdict()})
        failed = monitor.sweep()
        if failed:
            plan = coordinator.plan()
            print(f"[ft] failures={failed} plan={plan.action}")
    if ckpt:
        ckpt.wait()
    total = time.time() - t_start
    print(f"[done] {args.steps} steps in {total:.1f}s; "
          f"loss {losses[0]:.3f} → {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training did not reduce loss"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
