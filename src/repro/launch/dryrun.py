import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything else follows.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.hlo import parse_hlo_module               # noqa: E402
from repro.analysis import roofline as rl                     # noqa: E402
from repro.configs import (                                   # noqa: E402
    ARCH_IDS, SHAPES, cell_supported, get_config, input_specs,
)
from repro.launch.mesh import (                               # noqa: E402
    default_rules, make_production_mesh, mesh_device_count,
)
from repro.models import api as mapi                          # noqa: E402
from repro.models.module import (                             # noqa: E402
    abstract_params, partition_specs,
)
from repro.optim.adamw import AdamW                           # noqa: E402
from repro.sharding.ctx import use_sharding                   # noqa: E402
from repro.sharding.specs import (                            # noqa: E402
    cache_partition_specs, input_partition_specs, to_shardings,
)
from repro.train.step import TrainState, make_train_step      # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves: the sharding annotations are coherent at 256/512
chips, the program fits (memory_analysis), and produces the cost/collective
numbers §Roofline consumes. No arrays are ever allocated — everything lowers
from ShapeDtypeStruct stand-ins.

Usage:
    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""


def _abstract_opt_state(aparams):
    sds = lambda: jax.ShapeDtypeStruct((), jnp.int32)
    from repro.optim.adamw import AdamWState
    return AdamWState(sds(), jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), aparams),
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                     aparams))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline"):
    """Lower + compile one cell; returns a JSON-able result dict."""
    cfg = get_config(arch)
    if variant != "baseline":
        from repro.configs.base import apply_variant
        cfg = apply_variant(cfg, variant)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "variant": variant}
    if not ok:
        return dict(base, status="skipped", reason=reason)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh_device_count(mesh)
    rules = default_rules(mesh)
    spec = mapi.spec(cfg)
    aparams = abstract_params(spec)
    pspecs = partition_specs(spec, mesh, rules)
    pshard = to_shardings(mesh, pspecs)
    ins = input_specs(cfg, shape)
    in_sh = to_shardings(mesh, input_partition_specs(mesh, rules, ins))

    with mesh, use_sharding(mesh, rules):
        if shape.kind == "train":
            opt = AdamW()
            step_fn = make_train_step(cfg, opt)
            astate = TrainState(aparams, _abstract_opt_state(aparams), None,
                                jax.ShapeDtypeStruct((), jnp.int32))
            state_sh = TrainState(
                pshard, type(astate.opt)(
                    NamedSharding(mesh, P()),
                    pshard, jax.tree.map(lambda s: s, pshard)),
                None, NamedSharding(mesh, P()))
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, in_sh),
                donate_argnums=(0,),
            ).lower(astate, ins)
        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                logits, caches = mapi.prefill(params, cfg, batch,
                                              shape.seq_len)
                return logits[:, -1:], caches

            lowered = jax.jit(
                prefill_fn, in_shardings=(pshard, in_sh),
            ).lower(aparams, ins)
        else:  # decode
            acaches = mapi.cache_abstract(cfg, shape.global_batch,
                                          shape.seq_len,
                                          enc_len=shape.seq_len)
            cache_sh = to_shardings(
                mesh, cache_partition_specs(cfg, mesh, rules, acaches))

            def decode_fn(params, caches, token, pos):
                return mapi.decode_step(params, cfg, caches, token, pos)

            lowered = jax.jit(
                decode_fn,
                in_shardings=(pshard, cache_sh, in_sh["token"],
                              in_sh["pos"]),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(aparams, acaches, ins["token"], ins["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- analyses -----------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}
    try:
        ca = dict(compiled.cost_analysis())
        cost_d = {k: float(v) for k, v in ca.items()
                  if isinstance(v, (int, float)) and k in
                  ("flops", "bytes accessed", "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        cost_d = {"error": str(e)}

    hlo_text = compiled.as_text()
    stats = parse_hlo_module(hlo_text)
    hlo_path = None
    if os.environ.get("REPRO_SAVE_HLO", "1") != "0":
        out_dir = os.environ.get("REPRO_HLO_DIR", "results/hlo")
        os.makedirs(out_dir, exist_ok=True)
        import zstandard
        tag = f"{arch}__{shape_name}__{mesh_name}"
        if variant != "baseline":
            tag += f"__{variant}"
        hlo_path = os.path.join(out_dir, tag + ".hlo.zst")
        with open(hlo_path, "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(
                hlo_text.encode()))

    sp = mapi.spec(cfg)
    n_params = rl.active_param_count(sp)
    moe = cfg.moe
    n_active = rl.active_param_count(
        sp, moe.top_k if moe else None, moe.n_experts if moe else None)
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill")
                                   else 1)
    mf = rl.model_flops(n_params, n_active, tokens, shape.kind)
    roof = rl.analyze(stats, mf, n_chips)

    return dict(
        base,
        status="ok",
        hlo_path=hlo_path,
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        params=n_params,
        active_params=n_active,
        tokens_per_step=tokens,
        memory_analysis=mem_d,
        cost_analysis=cost_d,
        hlo=dict(
            flops=stats.flops,
            dot_flops=stats.dot_flops,
            bytes_accessed=stats.bytes_accessed,
            collective_bytes=stats.collective_bytes,
            collective_breakdown=stats.collective_breakdown,
            while_trip_counts=stats.while_trip_counts,
            warnings=stats.warnings[:5],
        ),
        roofline=roof.as_dict(),
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS) + ["all"],
                    default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"],
                    default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    res = lower_cell(arch, shape, mp, args.variant)
                except Exception:
                    failures += 1
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error",
                           "traceback": traceback.format_exc()}
                    print(f"[FAIL] {tag}", file=sys.stderr)
                    traceback.print_exc()
                    if args.fail_fast:
                        with open(path, "w") as f:
                            json.dump(res, f, indent=2)
                        return 1
                with open(path, "w") as f:
                    json.dump(res, f, indent=2)
                status = res["status"]
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" comp={r['compute_s']:.3e}s"
                             f" mem={r['memory_s']:.3e}s"
                             f" coll={r['collective_s']:.3e}s"
                             f" mfu={r['mfu']:.3f}"
                             f" compile={res['compile_s']:.0f}s")
                elif status == "skipped":
                    extra = " " + res["reason"]
                print(f"[{status:7s}] {tag}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
