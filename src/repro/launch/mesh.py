"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state. Single-pod: 16×16 =
256 chips (data × model). Multi-pod: 2×16×16 = 512 chips with a leading
pure-DP "pod" axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.models.module import MeshRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # test-only override (used by tests/test_dryrun_small.py to exercise the
    # full dry-run path on a handful of host devices)
    import os
    env = os.environ.get("REPRO_MESH_MULTI" if multi_pod
                         else "REPRO_MESH_SINGLE")
    if env:
        shape = tuple(int(x) for x in env.split(","))
        assert len(shape) == len(axes), (shape, axes)
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """General mesh for tests / elastic replans."""
    return jax.make_mesh(shape, axes)


def default_rules(mesh) -> MeshRules:
    """MeshRules filtered to the axes the mesh actually has."""
    names = tuple(mesh.shape.keys())
    return MeshRules(
        fsdp=tuple(a for a in ("data",) if a in names),
        tensor=tuple(a for a in ("model",) if a in names),
        batch=tuple(a for a in ("pod", "data") if a in names),
    )


def mesh_device_count(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
