"""Batched serving driver: prefill a batch of prompts, decode N tokens.

CPU-scale usage (reduced config):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import api as mapi
from repro.models.module import init_params
from repro.serve.step import make_decode_step, make_prefill_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    b, s, n_new = args.batch, args.prompt_len, args.new_tokens
    max_seq = s + n_new + (cfg.n_img_tokens if cfg.family == "vlm" else 0)

    params = init_params(jax.random.key(args.seed), mapi.spec(cfg))
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                         jnp.int32)
    batch = {"tokens": prompt}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_img_tokens, cfg.img_embed_dim)),
            jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg, max_seq))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    pos0 = s + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    outs = [tok]
    t0 = time.time()
    for i in range(n_new - 1):
        _, tok, caches = decode(params, caches, tok, jnp.int32(pos0 + i))
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"[serve] arch={cfg.arch_id} batch={b} prompt={s} new={n_new}")
    print(f"[serve] prefill {t_prefill*1e3:.0f} ms; decode "
          f"{t_decode/max(1, n_new-1)*1e3:.1f} ms/tok; "
          f"throughput {(b*(n_new-1))/max(t_decode,1e-9):.1f} tok/s")
    print(f"[serve] sample tokens: {np.asarray(gen[0, :16])}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
