"""Serving drivers: the relational query-serving tier and the LM demo.

Relational serving (the paper's premise — queries over big matrix data as
a service): spin a ``ServeEngine`` over a synthetic catalog and serve a
zipf multi-tenant workload, printing sustained qps and p50/p99 latency
with and without cross-query CSE:

    PYTHONPATH=src python -m repro.launch.serve --relational \
        --clients 1000 --dim 48 --threads 2

LM serving (the seed scaffolding, kept): prefill a batch of prompts and
decode N tokens through the hoisted compiled steps (``repro.serve.step``
— compiled once per (cfg, shape), decode caches donated):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import api as mapi
from repro.models.module import init_params
from repro.serve.step import compiled_decode, compiled_prefill


def serve_relational(args) -> int:
    import json

    from repro.core import Session
    from repro.obs.ledger import CostLedger
    from repro.serve import workload as wl

    rng = np.random.default_rng(args.seed)
    cost_model = None
    if args.costmodel_out or args.refit_every:
        from repro.core.calibrate import CostModel
        cost_model = CostModel(args.costmodel_out or None)
    session = Session(block_size=args.block_size, cost_model=cost_model)
    mats = wl.synthetic_catalog(session, rng, n=args.dim)
    templates = wl.query_templates(mats)
    stream = wl.client_stream(rng, templates, n_clients=args.clients,
                              n_tenants=args.tenants)
    print(f"[serve] catalog={list(mats)} templates={len(templates)} "
          f"clients={args.clients} tenants={args.tenants}")
    ledger = None
    if args.ledger_out or args.metrics_out or args.refit_every:
        # refit without an explicit output still needs the in-memory
        # rows as its fitting corpus
        ledger = CostLedger(args.ledger_out or None)
    snapshots = {}
    perf = {}
    violations = []
    for cse in (True, False):
        r = wl.run_workload(session, stream, cse=cse,
                            n_threads=args.threads,
                            tenant_max_inflight=args.tenant_inflight,
                            trace_sample=args.trace_sample,
                            ledger=ledger,
                            measure_comm=args.measure_comm,
                            refit_every=args.refit_every,
                            deadline_s=args.deadline)
        st = r["stats"]
        arm = f"cse_{'on' if cse else 'off'}"
        snapshots[arm] = st
        perf[arm] = {k: r[k] for k in ("queries", "wall_s", "qps",
                                       "p50_ms", "p99_ms", "failures",
                                       "hung", "admission_backoffs")}
        print(f"[serve] cse={'on ' if cse else 'off'} "
              f"qps={r['qps']:.0f} p50={r['p50_ms']:.2f}ms "
              f"p99={r['p99_ms']:.2f}ms root_hits={st['root_hits']} "
              f"shared_nodes={st['inter_query_cse_nodes']} "
              f"leaf_scans={st['leaf_scans']}/{st['leaf_refs']}"
              + (f" refits={st['refits']}" if args.refit_every else "")
              + (f" failures={r['failures']} hung={r['hung']} "
                 f"worker_restarts={st['worker_restarts']}"
                 if r["failures"] or r["hung"] or st["worker_crashes"]
                 else ""))
        # the chaos job's liveness gate (docs/robustness.md): every
        # admitted ticket must reach a terminal state, and the counters
        # must balance — a hung client or a lost/double-counted
        # completion is a hard failure, faults or no faults
        if st["completed"] + st["errors"] != st["submitted"]:
            violations.append(
                f"{arm}: completed({st['completed']}) + "
                f"errors({st['errors']}) != submitted({st['submitted']})")
        if r["hung"]:
            violations.append(f"{arm}: {r['hung']} ticket(s) hung past "
                              "the client timeout")
    if cost_model is not None and args.costmodel_out:
        path = cost_model.save()
        print(f"[serve] cost model v{cost_model.version} "
              f"({', '.join(cost_model.fitted_devices()) or 'unfitted'})"
              f" → {path}")
    if args.metrics_out:
        from repro.runtime import faults
        out = {"engine": snapshots, "perf": perf,
               "faults": faults.stats()}
        if ledger is not None:
            out["ledger"] = {"path": args.ledger_out,
                             "summary": ledger.summary()}
        with open(args.metrics_out, "w") as f:
            json.dump(out, f, indent=2, default=str)
        print(f"[serve] metrics → {args.metrics_out}"
              + (f", ledger → {args.ledger_out}"
                 if args.ledger_out else ""))
    if ledger is not None:
        ledger.close()
    if args.assert_complete:
        if violations:
            for v in violations:
                print(f"[serve] COMPLETENESS VIOLATION: {v}")
            return 1
        print("[serve] completeness: all tickets terminal, "
              "completed+errors == submitted in every arm")
    return 0


def serve_lm(args) -> int:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    b, s, n_new = args.batch, args.prompt_len, args.new_tokens
    max_seq = s + n_new + (cfg.n_img_tokens if cfg.family == "vlm" else 0)

    params = init_params(jax.random.key(args.seed), mapi.spec(cfg))
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)),
                         jnp.int32)
    batch = {"tokens": prompt}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_img_tokens, cfg.img_embed_dim)),
            jnp.float32)

    # hoisted compiled steps: a second driver run in the same process (or
    # any repro.serve.step.generate call) reuses these executables
    prefill = compiled_prefill(cfg, max_seq)
    decode = compiled_decode(cfg, donate=True)

    t0 = time.time()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    pos0 = s + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    outs = [tok]
    t0 = time.time()
    for i in range(n_new - 1):
        _, tok, caches = decode(params, caches, tok, jnp.int32(pos0 + i))
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"[serve] arch={cfg.arch_id} batch={b} prompt={s} new={n_new}")
    print(f"[serve] prefill {t_prefill*1e3:.0f} ms; decode "
          f"{t_decode/max(1, n_new-1)*1e3:.1f} ms/tok; "
          f"throughput {(b*(n_new-1))/max(t_decode,1e-9):.1f} tok/s")
    print(f"[serve] sample tokens: {np.asarray(gen[0, :16])}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--relational", action="store_true",
                    help="serve the relational matrix-query workload "
                         "instead of the LM demo")
    ap.add_argument("--seed", type=int, default=0)
    # relational serving
    ap.add_argument("--clients", type=int, default=1000)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--dim", type=int, default=48)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--threads", type=int, default=2)
    ap.add_argument("--tenant-inflight", type=int, default=None,
                    help="admission: max queued+running per tenant")
    ap.add_argument("--trace-sample", type=float, default=None,
                    help="engine trace sampling rate (0..1; default: "
                         "REPRO_TRACE_SAMPLE / off)")
    ap.add_argument("--ledger-out", default=None,
                    help="append the predicted-vs-actual cost ledger "
                         "to this JSONL file")
    ap.add_argument("--metrics-out", default=None,
                    help="dump engine metric snapshots (+ ledger "
                         "summary) as JSON at exit")
    ap.add_argument("--measure-comm", action="store_true",
                    help="record measured collective bytes in ledger "
                         "rows (HLO-derived on a mesh, 0 off-mesh)")
    ap.add_argument("--refit-every", type=int, default=None,
                    help="online calibration: background-refit the "
                         "session cost model every N executed plans "
                         "from the accumulated ledger rows")
    ap.add_argument("--costmodel-out", default=None,
                    help="persist fitted cost-model coefficients "
                         "(core.calibrate) to this JSON at exit")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-ticket deadline seconds (queue wait + "
                         "execution); past it the engine finishes the "
                         "ticket with DeadlineExceeded")
    ap.add_argument("--assert-complete", action="store_true",
                    help="exit 1 unless every admitted ticket reached a "
                         "terminal state and completed+errors == "
                         "submitted (the CI chaos gate; pair with "
                         "REPRO_FAULTS=...)")
    # LM serving
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args(argv)
    if args.relational:
        return serve_relational(args)
    return serve_lm(args)


if __name__ == "__main__":
    raise SystemExit(main())
