"""Optimized-HLO text parser: FLOPs / HBM traffic / collective bytes.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` (scan) body
ONCE, regardless of trip count (verified empirically — a 4-step scan of a
256³ matmul reports 1× matmul flops). Since every model here scans over
layers, we parse the per-device optimized HLO ourselves and multiply
computation costs through the call graph, detecting scan trip counts from
the loop-condition constants.

Counting conventions:
  * FLOPs       — 2·numel(out)·K for every ``dot`` (K = contracted extent);
                  elementwise/reduce ops are counted at 1 flop/output element.
  * HBM bytes   — every non-fused op boundary is a materialization point:
                  operands + outputs of top-level ops (fusion internals are
                  free, which is exactly XLA's fusion-boundary cost model).
  * collective  — operand bytes summed over all-reduce / all-gather /
                  reduce-scatter / all-to-all / collective-permute (and their
                  async -start forms), as the brief specifies.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
    "opaque": 0, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)\)",
)
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "all-reduce-scatter", "ragged-all-to-all",
    "collective-broadcast",
}
_SKIP_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    "get-dimension-size", "opt-barrier", "optimization-barrier",
}


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_numel(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    result_type: str
    operands_str: str
    attrs_str: str
    line: str
    operand_types: List[str] = dataclasses.field(default_factory=list)
    operand_names: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo]
    symtab: Dict[str, str] = dataclasses.field(default_factory=dict)

    def resolve_operands(self) -> None:
        """Modern HLO dumps omit operand types; resolve via local names."""
        for op in self.ops:
            types: List[str] = []
            names: List[str] = []
            depth = 0
            token = ""
            parts: List[str] = []
            for ch in op.operands_str:
                if ch == "," and depth == 0:
                    parts.append(token)
                    token = ""
                    continue
                if ch in "([{":
                    depth += 1
                elif ch in ")]}":
                    depth -= 1
                token += ch
            if token.strip():
                parts.append(token)
            for part in parts:
                part = part.strip()
                if _SHAPE_RE.search(part):
                    types.append(part)  # inline type present (older dumps)
                    m = re.search(r"%([\w.\-]+)", part)
                    names.append(m.group(1) if m else "")
                    continue
                nm = part.lstrip("%")
                types.append(self.symtab.get(nm, ""))
                names.append(nm)
            op.operand_types = types
            op.operand_names = names


# Transcendental/special-function opcodes: far costlier than 1 flop/elem on
# every backend, so the cost-model feature vector tracks them separately
# (exactly what HloCostAnalysis's transcendental_count does).
TRANSCENDENTAL_OPS = {
    "exponential", "exponential-minus-one", "log", "log1p", "tanh",
    "rsqrt", "sqrt", "cbrt", "power", "logistic", "atan2", "expm1",
    "sin", "cos", "tan",
}

# The per-plan cost-model feature schema (shared with
# ``repro.core.calibrate.FEATURES`` — a test pins the correspondence).
# ``nnz`` is a plan-level notion with no HLO counterpart, so the HLO
# extractor emits 0.0 for it.
FEATURE_NAMES = ("dot_flops", "ew_flops", "bytes", "transcendentals",
                 "comm_bytes", "nnz", "ops")


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    dot_flops: float = 0.0
    transcendentals: float = 0.0      # elements through transcendental ops
    op_count: float = 0.0             # executed top-level ops (launches)
    while_trip_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    warnings: List[str] = dataclasses.field(default_factory=list)

    def merged_scaled(self, other: "HloStats", k: float) -> None:
        self.flops += other.flops * k
        self.bytes_accessed += other.bytes_accessed * k
        self.collective_bytes += other.collective_bytes * k
        self.dot_flops += other.dot_flops * k
        self.transcendentals += other.transcendentals * k
        self.op_count += other.op_count * k
        for op, b in other.collective_breakdown.items():
            self.collective_breakdown[op] = \
                self.collective_breakdown.get(op, 0.0) + b * k

    def feature_vector(self) -> Dict[str, float]:
        """This module's stats as the cost-model feature schema
        (``FEATURE_NAMES``): dot vs elementwise flops split, HBM traffic,
        transcendental elements, collective bytes and launch count."""
        return {
            "dot_flops": self.dot_flops,
            "ew_flops": max(self.flops - self.dot_flops, 0.0),
            "bytes": self.bytes_accessed,
            "transcendentals": self.transcendentals,
            "comm_bytes": self.collective_bytes,
            "nnz": 0.0,
            "ops": self.op_count,
        }


def _split_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry_name = ""
    cur: Optional[Computation] = None
    header_re = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(\([^)]*\))?.*\{\s*$")
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = header_re.match(line.strip())
            if m and "->" in line or (m and line.strip().endswith("{")):
                if m:
                    cur = Computation(m.group(2), [])
                    if m.group(1):
                        entry_name = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        om = _OP_RE.match(line)
        if om:
            name, rtype, opcode, rest = om.groups()
            # split operands from attrs: attrs follow the closing paren —
            # rest may contain nested parens from types; use the raw line
            attr_idx = line.find("), ")
            attrs = line[attr_idx + 3:] if attr_idx >= 0 else ""
            cur.ops.append(OpInfo(name, opcode, rtype, rest, attrs, line))
            cur.symtab[name] = rtype
    for comp in comps.values():
        comp.resolve_operands()
    return comps, entry_name


def _op_in_bytes(op: OpInfo) -> int:
    return sum(_shape_bytes(t) for t in op.operand_types)


# ---------------------------------------------------------------------------
# Fusion-aware HBM traffic model.
#
# CPU-lowered HLO is barely fused, so charging operands+outputs of every op
# wildly overestimates what XLA:TPU would move through HBM. We simulate the
# standard greedy producer fusion: a cheap (elementwise-ish) op with exactly
# one consumer joins its consumer's group; HBM traffic is charged only at
# group boundaries (deduped external inputs + externally-consumed outputs).
# Dynamic-slice/gather charge the slice, not the sliced buffer;
# dynamic-update-slice charges 2× the update (read-modify-write of the
# aliased region).
# ---------------------------------------------------------------------------

_FUSABLE = {
    "add", "multiply", "subtract", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "clamp", "rem", "and", "or", "xor", "not",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "is-finite", "atan2", "expm1", "log1p", "logistic", "cbrt", "cos",
    "sin", "tan", "round-nearest-afz", "round-nearest-even", "compare",
    "select", "convert", "broadcast", "iota", "reshape", "bitcast",
    "transpose", "pad", "slice", "reduce", "concatenate", "reverse", "map",
    "reduce-precision", "stochastic-convert", "exponential-minus-one",
    "copy",
}
_GROUP_BLOCKERS = {"while", "fusion", "call", "conditional", "custom-call",
                   "async-start"} | COLLECTIVE_OPS
_NO_DEF_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota", "opt-barrier", "optimization-barrier",
                   "get-dimension-size"}


def _edge_price(consumer: OpInfo, operand_idx: int, operand_type: str,
                comps: Optional[Dict[str, "Computation"]] = None) -> int:
    """Bytes read for one external tensor → op edge.

    dynamic-slice/gather read only the slice; dynamic-update-slice touches
    only the updated region; a FUSION that consumes the operand exclusively
    through dynamic-slice/gather on its matching parameter is priced at the
    slice size too (critical inside scan bodies, where per-layer weight and
    per-step activation slices are read from stacked arrays — charging the
    full stacked array once per iteration would overcount by the trip
    count)."""
    if consumer.opcode in ("dynamic-slice", "gather") and operand_idx == 0:
        return _shape_bytes(consumer.result_type)
    if consumer.opcode == "dynamic-update-slice" and operand_idx == 0:
        # aliased in-place update: read+write only the updated region
        upd = consumer.operand_types[1] if len(consumer.operand_types) > 1 \
            else consumer.result_type
        return _shape_bytes(upd)
    if consumer.opcode == "scatter" and operand_idx == 0:
        # in-place scatter: touched region ≈ updates (operand 2)
        upd = consumer.operand_types[2] if len(consumer.operand_types) > 2 \
            else consumer.result_type
        return _shape_bytes(upd)
    if consumer.opcode == "fusion" and comps is not None:
        m = _CALL_ATTR_RE.search(consumer.line)
        called = comps.get(m.group(1)) if m else None
        if called is not None:
            price = _fusion_param_price(called, operand_idx)
            if price is not None:
                return price
    return _shape_bytes(operand_type)


def _fusion_param_price(called: "Computation", idx: int) -> Optional[int]:
    """If parameter ``idx`` of a fused computation is consumed only via
    dynamic-slice / gather / DUS(op0), return the sliced byte count."""
    pname = None
    for op in called.ops:
        if op.opcode == "parameter" and f"parameter({idx})" in op.line:
            pname = op.name
            break
    if pname is None:
        return None
    total = 0
    seen = False
    for op in called.ops:
        if pname not in op.operand_names:
            continue
        seen = True
        oidx = op.operand_names.index(pname)
        if op.opcode in ("dynamic-slice", "gather") and oidx == 0:
            total += _shape_bytes(op.result_type)
        elif op.opcode == "dynamic-update-slice" and oidx == 0:
            upd = op.operand_types[1] if len(op.operand_types) > 1 \
                else op.result_type
            total += _shape_bytes(upd)
        else:
            return None  # consumed wholesale somewhere: full price
    return total if seen else 0


def _traffic(comp: Computation,
             comps: Optional[Dict[str, Computation]] = None
             ) -> float:
    name2op = {op.name: op for op in comp.ops}
    consumers: Dict[str, List[OpInfo]] = {}
    for op in comp.ops:
        for nm in op.operand_names:
            if nm in name2op:
                consumers.setdefault(nm, []).append(op)

    parent: Dict[str, str] = {op.name: op.name for op in comp.ops}

    def find(x: str) -> str:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for op in comp.ops:
        cons = consumers.get(op.name, [])
        if (op.opcode in _FUSABLE and len(cons) == 1
                and cons[0].opcode not in _GROUP_BLOCKERS):
            parent[find(op.name)] = find(cons[0].name)

    # fixpoint: a fusable op whose consumers all landed in ONE group joins it
    # (XLA fusions allow multi-use internal values — e.g. the flash-softmax
    # pattern where the logits tensor feeds both the running max and the exp)
    for _ in range(8):
        changed = False
        for op in comp.ops:
            cons = consumers.get(op.name, [])
            if op.opcode not in _FUSABLE or len(cons) < 2:
                continue
            if any(c.opcode in _GROUP_BLOCKERS for c in cons):
                continue
            tgt = {find(c.name) for c in cons}
            if len(tgt) == 1 and find(op.name) not in tgt:
                parent[find(op.name)] = tgt.pop()
                changed = True
        if not changed:
            break

    groups: Dict[str, List[OpInfo]] = {}
    for op in comp.ops:
        groups.setdefault(find(op.name), []).append(op)

    total = 0.0
    root_name = comp.ops[-1].name if comp.ops else None
    zero_charge = {"while", "call", "conditional", "async-start"}
    for gid, members in groups.items():
        mset = {m.name for m in members}
        if all(m.opcode in _NO_DEF_TRAFFIC | zero_charge for m in members):
            continue
        ext_in: Dict[str, int] = {}
        for m in members:
            if m.opcode in zero_charge:
                continue  # internals charged via recursion, not boundary
            for idx, (nm, ty) in enumerate(zip(m.operand_names,
                                               m.operand_types)):
                if nm in mset or not ty:
                    continue
                src = name2op.get(nm)
                if src is not None and src.opcode == "constant" \
                        and _shape_numel(src.result_type) <= 256:
                    continue  # small constants live in registers/immediate
                price = _edge_price(m, idx, ty, comps)
                ext_in[nm] = max(ext_in.get(nm, 0), price)
        out_bytes = 0
        for m in members:
            if m.opcode in _NO_DEF_TRAFFIC or m.opcode in zero_charge:
                continue
            ext_cons = [c for c in consumers.get(m.name, [])
                        if c.name not in mset]
            if ext_cons or m.name == root_name \
                    or not consumers.get(m.name):
                if m.opcode == "dynamic-update-slice":
                    upd = m.operand_types[1] if len(m.operand_types) > 1 \
                        else m.result_type
                    out_bytes += _shape_bytes(upd)
                elif m.opcode == "scatter":
                    upd = m.operand_types[2] if len(m.operand_types) > 2 \
                        else m.result_type
                    out_bytes += _shape_bytes(upd)
                else:
                    out_bytes += _shape_bytes(m.result_type)
        total += sum(ext_in.values()) + out_bytes
    return total


def _dot_flops(op: OpInfo) -> float:
    out_numel = _shape_numel(op.result_type)
    lhs_m = _SHAPE_RE.search(op.operand_types[0]) if op.operand_types else None
    if lhs_m is None:
        return 0.0
    lhs_dims = [int(d) for d in lhs_m.group(2).split(",")] \
        if lhs_m.group(2) else []
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    k = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                k *= lhs_dims[di]
    return 2.0 * out_numel * k


def _conv_flops(op: OpInfo) -> float:
    # rough: 2 * out_numel * (kernel spatial * in_channels); estimated from
    # the rhs (kernel) operand numel divided by output feature dim if found
    out_numel = _shape_numel(op.result_type)
    if len(op.operand_types) < 2:
        return 2.0 * out_numel
    m = _SHAPE_RE.search(op.operand_types[1])
    rhs_dims = [int(d) for d in m.group(2).split(",")] if m and m.group(2) else []
    if not rhs_dims:
        return 2.0 * out_numel
    k = 1
    for d in rhs_dims[:-1]:
        k *= d
    return 2.0 * out_numel * k


def _trip_count(cond: Computation) -> Optional[int]:
    """JAX scans lower to while(cond: iter < C). Return the compare bound."""
    consts: Dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant" and op.result_type.strip().startswith("s"):
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                consts[op.name] = int(m.group(1))
    bounds = []
    for op in cond.ops:
        if op.opcode == "compare":
            names = re.findall(r"%([\w.\-]+)", op.operands_str)
            for nm in names:
                if nm in consts:
                    bounds.append(consts[nm])
    if bounds:
        return max(bounds)
    if consts:
        return max(consts.values())
    return None


def _analyze(comp: Computation, comps: Dict[str, Computation],
             memo: Dict[str, HloStats]) -> HloStats:
    if comp.name in memo:
        return memo[comp.name]
    stats = HloStats()
    memo[comp.name] = stats  # placed first to break accidental cycles
    # HBM traffic: fusion-aware group model over this computation's ops
    # (control-flow/called computations contribute via recursion below)
    stats.bytes_accessed += _traffic(comp, comps)
    for op in comp.ops:
        out_bytes = _shape_bytes(op.result_type)
        in_bytes = _op_in_bytes(op)
        if op.opcode not in _SKIP_TRAFFIC:
            # every executed op is (roughly) one kernel launch; a fusion is
            # one launch regardless of its internals, while/call bodies add
            # theirs via merged_scaled below
            stats.op_count += 1.0
        if op.opcode in TRANSCENDENTAL_OPS:
            stats.transcendentals += _shape_numel(op.result_type)
        if op.opcode == "dot":
            f = _dot_flops(op)
            stats.flops += f
            stats.dot_flops += f
        elif op.opcode == "convolution":
            stats.flops += _conv_flops(op)
        elif op.opcode in COLLECTIVE_OPS:
            b = in_bytes
            stats.collective_bytes += b
            key = op.opcode.replace("-start", "")
            stats.collective_breakdown[key] = \
                stats.collective_breakdown.get(key, 0.0) + b
        elif op.opcode == "fusion":
            # the fusion op is a single group: boundary traffic is charged by
            # _traffic at the call site; internals add flops/collectives/
            # transcendentals only (op_count stays 1 — one launch)
            m = _CALL_ATTR_RE.search(op.line)
            if m and m.group(1) in comps:
                inner = _analyze(comps[m.group(1)], comps, memo)
                stats.flops += inner.flops
                stats.dot_flops += inner.dot_flops
                stats.transcendentals += inner.transcendentals
                stats.collective_bytes += inner.collective_bytes
                for k2, v in inner.collective_breakdown.items():
                    stats.collective_breakdown[k2] = \
                        stats.collective_breakdown.get(k2, 0.0) + v
        elif op.opcode == "while":
            body_name = cond_name = None
            bm = re.search(r"body=%?([\w.\-]+)", op.line)
            cm = re.search(r"condition=%?([\w.\-]+)", op.line)
            if bm:
                body_name = bm.group(1)
            if cm:
                cond_name = cm.group(1)
            trips = None
            if cond_name and cond_name in comps:
                trips = _trip_count(comps[cond_name])
            if trips is None:
                trips = 1
                stats.warnings.append(
                    f"while {op.name}: trip count unknown, assuming 1")
            stats.while_trip_counts[op.name] = trips
            if body_name and body_name in comps:
                inner = _analyze(comps[body_name], comps, memo)
                stats.merged_scaled(inner, trips)
                for wn, tc in inner.while_trip_counts.items():
                    stats.while_trip_counts[f"{op.name}/{wn}"] = tc
        elif op.opcode in ("call", "async-start", "custom-call"):
            m = _CALL_ATTR_RE.search(op.line)
            if m and m.group(1) in comps:
                inner = _analyze(comps[m.group(1)], comps, memo)
                stats.merged_scaled(inner, 1.0)
        elif op.opcode == "conditional":
            bm = _BRANCH_RE.search(op.line)
            if bm:
                branches = re.findall(r"%?([\w.\-]+)", bm.group(1))
                if branches:
                    # charge the most expensive branch (worst case)
                    inners = [_analyze(comps[b], comps, memo)
                              for b in branches if b in comps]
                    if inners:
                        worst = max(inners, key=lambda s: s.flops)
                        stats.merged_scaled(worst, 1.0)
        elif op.opcode in _SKIP_TRAFFIC:
            pass
        elif op.opcode in ("reduce", "reduce-window", "scatter", "gather",
                           "sort", "copy", "transpose", "reshape",
                           "broadcast", "concatenate", "slice",
                           "dynamic-slice", "dynamic-update-slice", "pad",
                           "convert", "select", "compare", "add", "multiply",
                           "subtract", "divide", "exponential", "log",
                           "tanh", "rsqrt", "sqrt", "maximum", "minimum",
                           "negate", "abs", "power", "rng", "rng-bit-generator",
                           "cbrt", "logistic", "sign", "floor", "ceil",
                           "clamp", "rem", "and", "or", "xor", "not",
                           "shift-left", "shift-right-logical",
                           "shift-right-arithmetic", "is-finite", "atan2",
                           "expm1", "log1p", "round-nearest-afz",
                           "round-nearest-even", "stochastic-convert",
                           "reverse", "map", "reduce-precision", "cos",
                           "sin", "tan", "real", "imag", "complex"):
            stats.flops += _shape_numel(op.result_type)
        else:
            pass  # unknown op: traffic handled by the group model
    return stats


def parse_hlo_module(text: str) -> HloStats:
    comps, entry = _split_computations(text)
    if not comps:
        raise ValueError("no computations parsed from HLO text")
    if not entry:
        # fall back: the computation that is not referenced by any other
        referenced = set()
        for c in comps.values():
            for op in c.ops:
                for m in _CALL_ATTR_RE.finditer(op.line):
                    referenced.add(m.group(1))
        candidates = [n for n in comps if n not in referenced]
        entry = candidates[-1] if candidates else list(comps)[-1]
    memo: Dict[str, HloStats] = {}
    top = _analyze(comps[entry], comps, memo)
    out = HloStats()
    out.merged_scaled(top, 1.0)
    out.while_trip_counts = dict(top.while_trip_counts)
    out.warnings = list(top.warnings)
    return out
