"""Recompute roofline stats from saved (zstd-compressed) HLO dumps.

Lets the §Perf loop iterate on the analysis model without recompiling, and
regenerates every cell JSON after parser improvements:

    PYTHONPATH=src python -m repro.analysis.reanalyze results/dryrun
"""
from __future__ import annotations

import json
import os
import sys

import zstandard

from repro.analysis import roofline as rl
from repro.analysis.hlo import parse_hlo_module


def reanalyze_cell(json_path: str) -> bool:
    with open(json_path) as f:
        res = json.load(f)
    if res.get("status") != "ok" or not res.get("hlo_path"):
        return False
    hp = res["hlo_path"]
    if not os.path.exists(hp):
        return False
    with open(hp, "rb") as f:
        text = zstandard.ZstdDecompressor().decompress(f.read()).decode()
    stats = parse_hlo_module(text)
    mf = rl.model_flops(res["params"], res["active_params"],
                        res["tokens_per_step"],
                        "train" if res["shape"].startswith("train")
                        else ("prefill" if res["shape"].startswith("prefill")
                              else "decode"))
    roof = rl.analyze(stats, mf, res["n_chips"])
    res["hlo"] = dict(
        flops=stats.flops, dot_flops=stats.dot_flops,
        bytes_accessed=stats.bytes_accessed,
        collective_bytes=stats.collective_bytes,
        collective_breakdown=stats.collective_breakdown,
        while_trip_counts=stats.while_trip_counts,
        warnings=stats.warnings[:5])
    res["roofline"] = roof.as_dict()
    with open(json_path, "w") as f:
        json.dump(res, f, indent=2)
    return True


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    d = args[0] if args else "results/dryrun"
    n = 0
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            if reanalyze_cell(os.path.join(d, fn)):
                n += 1
                r = json.load(open(os.path.join(d, fn)))["roofline"]
                print(f"[reanalyzed] {fn[:-5]} dom={r['dominant']} "
                      f"mfu={r['mfu']:.3f}")
    print(f"{n} cells reanalyzed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
