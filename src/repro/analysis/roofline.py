"""Three-term roofline model from the compiled dry-run artifact.

Per (arch × shape × mesh), using the per-device optimized HLO (already
SPMD-partitioned, so every number is per-chip):

    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes / HBM_bw
    collective = collective_bytes / ICI_bw

Hardware constants (TPU v5e per the brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. MODEL_FLOPS = 6·N·D (dense train) / 6·N_active·D (MoE)
/ 2·N·D (inference) is reported alongside as the usefulness ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo import HloStats

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes / s / chip
ICI_BW = 50e9             # bytes / s / link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops_per_chip: float
    usefulness: float          # MODEL_FLOPS / HLO_FLOPs (per chip)
    dominant: str
    step_time_s: float         # max of the three terms (no overlap model)
    mfu: float                 # model_flops / (step_time × peak)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(stats: HloStats, model_flops_total: float, n_chips: int,
            peak=PEAK_FLOPS, hbm=HBM_BW, ici=ICI_BW) -> Roofline:
    compute = stats.flops / peak
    memory = stats.bytes_accessed / hbm
    collective = stats.collective_bytes / ici
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    model_pc = model_flops_total / max(1, n_chips)
    step = max(compute, memory, collective)
    return Roofline(
        compute_s=compute, memory_s=memory, collective_s=collective,
        hlo_flops=stats.flops, hlo_bytes=stats.bytes_accessed,
        collective_bytes=stats.collective_bytes,
        model_flops_per_chip=model_pc,
        usefulness=model_pc / max(stats.flops, 1.0),
        dominant=dominant,
        step_time_s=step,
        mfu=model_pc / max(step, 1e-12) / peak,
    )


def model_flops(param_count: int, active_param_count: int, tokens: int,
                kind: str) -> float:
    """6·N·D train / 2·N·D inference (N = active params for MoE)."""
    n = active_param_count
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def active_params(spec_tree) -> int:
    """Parameter count with MoE expert tensors scaled by top_k/E."""
    import math

    import jax

    from repro.models.module import ParamSpec, is_param_spec

    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            spec_tree, is_leaf=is_param_spec)[0]:
        assert isinstance(leaf, ParamSpec)
        n = int(math.prod(leaf.shape))
        if "experts" in (leaf.axes or ()):
            # scale by routed fraction later (caller passes top_k/E)
            pass
        total += n
    return total


def active_param_count(spec_tree, top_k: Optional[int] = None,
                       n_experts: Optional[int] = None) -> int:
    import math

    import jax

    from repro.models.module import ParamSpec, is_param_spec

    total = 0
    for _, leaf in jax.tree_util.tree_flatten_with_path(
            spec_tree, is_leaf=is_param_spec)[0]:
        n = int(math.prod(leaf.shape))
        if top_k and n_experts and "experts" in (leaf.axes or ()):
            n = int(n * top_k / n_experts)
        total += n
    return total
