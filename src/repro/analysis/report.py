"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from cell JSONs.

    PYTHONPATH=src python -m repro.analysis.report results/dryrun
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List


def _fmt_bytes(b) -> str:
    if b is None:
        return "—"
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def load_cells(d: str) -> List[Dict]:
    out = []
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            with open(os.path.join(d, fn)) as f:
                out.append(json.load(f))
    return out


def dryrun_table(cells: List[Dict], mesh: str) -> str:
    rows = ["| arch | shape | status | chips | params | bytes/chip (temp) "
            "| HLO GFLOPs/chip | coll GB/chip | collective mix | compile s |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["mesh"] != mesh or c.get("variant", "baseline") != "baseline":
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | SKIP | — | — | — "
                        f"| — | — | {c['reason'].split(':')[0]} | — |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | ERROR | | | | | "
                        f"| | |")
            continue
        h = c["hlo"]
        mix = ", ".join(f"{k.replace('all-', 'a')}:{_fmt_bytes(v)}"
                        for k, v in sorted(
                            h["collective_breakdown"].items(),
                            key=lambda kv: -kv[1]) if v > 0) or "none"
        temp = c["memory_analysis"].get("temp_bytes")
        rows.append(
            f"| {c['arch']} | {c['shape']} | ok | {c['n_chips']} "
            f"| {c['params'] / 1e9:.2f}B | {_fmt_bytes(temp)} "
            f"| {h['flops'] / 1e9:,.0f} | {h['collective_bytes'] / 1e9:.2f} "
            f"| {mix} | {c['compile_s']:.0f} |")
    return "\n".join(rows)


def roofline_table(cells: List[Dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant "
            "| MODEL_FLOPS/HLO | MFU@roofline |",
            "|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["mesh"] != mesh or c.get("variant", "baseline") != "baseline":
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"SKIP(full-attn) | — | — |")
            continue
        if c["status"] != "ok":
            continue
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['usefulness']:.2f} "
            f"| {r['mfu']:.4f} |")
    return "\n".join(rows)


def perf_table(cells: List[Dict], arch: str, shape: str) -> str:
    rows = [f"**{arch} × {shape}** (single-pod, per chip)",
            "",
            "| variant | compute s | memory s | collective s | dominant "
            "| step s | MFU |",
            "|---|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("arch") != arch or c.get("shape") != shape \
                or c.get("status") != "ok":
            continue
        r = c["roofline"]
        rows.append(
            f"| {c.get('variant', 'baseline')} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| {r['dominant']} | {r['step_time_s']:.3e} | {r['mfu']:.4f} |")
    return "\n".join(rows)


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    d = args[0] if args else "results/dryrun"
    cells = load_cells(d)
    mode = args[1] if len(args) > 1 else "all"
    if mode in ("all", "dryrun"):
        print("### Single-pod (16×16 = 256 chips)\n")
        print(dryrun_table(cells, "single"))
        print("\n### Multi-pod (2×16×16 = 512 chips)\n")
        print(dryrun_table(cells, "multi"))
    if mode in ("all", "roofline"):
        print("\n### Roofline (single-pod)\n")
        print(roofline_table(cells, "single"))
    if mode == "perf":
        arch, shape = args[2], args[3]
        print(perf_table(cells, arch, shape))
    return 0


if __name__ == "__main__":
    sys.exit(main())
